//! Convolution tiling: the NVDLA-dataflow-specialized optimizer.
//!
//! Handles the edge cases the paper calls out: halo regions from SAME
//! zero-padding, overlapping input rows between adjacent spatial tiles,
//! stride > 1 interactions, and non-uniform edge tiles.

use super::{
    region_copy_stats, CopyStats, GemmDims, Region, TilingPlan, TilingStrategy,
    WorkItem,
};
use crate::config::SocConfig;
use crate::tensor::Shape;
use crate::util::ceil_div;

/// Convolution operator parameters (single-batch NHWC input).
#[derive(Debug, Clone, Copy)]
pub struct ConvParams {
    /// Input rows.
    pub h: usize,
    /// Input cols.
    pub w: usize,
    /// Input channels.
    pub c: usize,
    /// Output channels.
    pub k: usize,
    /// Kernel rows (R).
    pub r: usize,
    /// Kernel cols (S).
    pub s: usize,
    /// Stride (same in both spatial dims).
    pub stride: usize,
    /// SAME zero padding (else VALID).
    pub pad_same: bool,
}

impl ConvParams {
    /// Output spatial dims.
    pub fn out_dims(&self) -> (usize, usize) {
        if self.pad_same {
            (ceil_div(self.h, self.stride), ceil_div(self.w, self.stride))
        } else {
            (
                (self.h - self.r) / self.stride + 1,
                (self.w - self.s) / self.stride + 1,
            )
        }
    }

    /// Total zero padding in (rows, cols) for SAME.
    fn total_pad(&self) -> (usize, usize) {
        if !self.pad_same {
            return (0, 0);
        }
        let (oh, ow) = self.out_dims();
        (
            ((oh - 1) * self.stride + self.r).saturating_sub(self.h),
            ((ow - 1) * self.stride + self.s).saturating_sub(self.w),
        )
    }

    /// Total multiply-accumulates for the layer.
    pub fn total_macs(&self) -> u64 {
        let (oh, ow) = self.out_dims();
        (oh * ow * self.k * self.r * self.s * self.c) as u64
    }
}

/// Tile extents chosen by the optimizer (output-space spatial extents).
#[derive(Debug, Clone, Copy)]
struct TileDims {
    oh_t: usize,
    ow_t: usize,
    c_t: usize,
    k_t: usize,
}

/// Shrink tile dims under `strategy` until all scratchpad constraints fit.
/// Returns `None` if the strategy cannot satisfy the constraints.
fn fit_tile(p: &ConvParams, strategy: TilingStrategy, soc: &SocConfig) -> Option<TileDims> {
    let (oh, ow) = p.out_dims();
    let spad = soc.spad_elems();
    let macc = soc.nvdla_macc_width;
    let mut d = TileDims {
        oh_t: oh,
        ow_t: ow,
        c_t: p.c,
        k_t: p.k,
    };
    // Input tile includes the halo; sizes in input space.
    let in_elems = |d: &TileDims| {
        let ih = (d.oh_t - 1) * p.stride + p.r;
        let iw = (d.ow_t - 1) * p.stride + p.s;
        ih * iw * d.c_t
    };
    let wgt_elems = |d: &TileDims| d.k_t * p.r * p.s * d.c_t;
    let out_elems = |d: &TileDims| d.oh_t * d.ow_t * d.k_t;
    // GEMM command-descriptor limits: one accelerator pass handles at most
    // M=1024 output pixels, K=2048 reduction depth, N=256 output channels
    // (the canonical tile grid the AOT artifacts are compiled for).
    let gemm_ok = |d: &TileDims| {
        d.oh_t * d.ow_t <= crate::runtime::CANONICAL_M[crate::runtime::CANONICAL_M.len() - 1]
            && p.r * p.s * d.c_t
                <= crate::runtime::CANONICAL_K[crate::runtime::CANONICAL_K.len() - 1]
            && d.k_t <= crate::runtime::CANONICAL_N[crate::runtime::CANONICAL_N.len() - 1]
    };

    // The output-channel dimension of the *weights* is always tileable
    // (it has no software-copy cost: weights are pre-tiled offline).
    // First shrink K to the PE count granularity while weights/outputs
    // overflow and shrinking K alone can help.
    let pes = soc.nvdla_pes;
    let n_cap = crate::runtime::CANONICAL_N[crate::runtime::CANONICAL_N.len() - 1];
    d.k_t = d.k_t.min(n_cap);
    while (wgt_elems(&d) > spad || out_elems(&d) > spad) && d.k_t > pes {
        d.k_t = ((d.k_t / 2).max(pes) / pes) * pes;
    }
    // Then shrink the strategy's tiled dimensions in preference order
    // H -> W -> C (H/W halve; C steps down in MACC-width multiples).
    let mut guard = 0;
    while in_elems(&d) > spad || wgt_elems(&d) > spad || out_elems(&d) > spad || !gemm_ok(&d) {
        guard += 1;
        if guard > 64 {
            return None;
        }
        // K-depth cap can only be fixed by shrinking channels.
        let k_cap = crate::runtime::CANONICAL_K[crate::runtime::CANONICAL_K.len() - 1];
        let m_cap = crate::runtime::CANONICAL_M[crate::runtime::CANONICAL_M.len() - 1];
        let need_c = p.r * p.s * d.c_t > k_cap;
        let need_m = d.oh_t * d.ow_t > m_cap;
        if need_c && d.c_t > 1 {
            if !strategy.c {
                return None;
            }
            if d.c_t > macc {
                d.c_t = ((d.c_t - 1) / macc).max(1) * macc;
            } else {
                d.c_t = ceil_div(d.c_t, 2);
            }
            continue;
        }
        if need_m && (strategy.h || strategy.w) {
            if strategy.h && d.oh_t >= d.ow_t && d.oh_t > 1 {
                d.oh_t = ceil_div(d.oh_t, 2);
                continue;
            }
            if strategy.w && d.ow_t > 1 {
                d.ow_t = ceil_div(d.ow_t, 2);
                continue;
            }
        }
        if strategy.h && d.oh_t > 1 {
            d.oh_t = ceil_div(d.oh_t, 2);
            continue;
        }
        if strategy.w && d.ow_t > 1 {
            d.ow_t = ceil_div(d.ow_t, 2);
            continue;
        }
        if strategy.c && d.c_t > macc {
            // Largest multiple of the MACC width below current.
            d.c_t = ((d.c_t - 1) / macc).max(1) * macc;
            continue;
        }
        if strategy.c && d.c_t > 1 && d.c_t <= macc {
            d.c_t = ceil_div(d.c_t, 2);
            continue;
        }
        return None; // Constraints unsatisfiable under this strategy.
    }
    Some(d)
}

/// Cheap cost summary of a fitted tile shape, without materializing the
/// work items (strategy ranking is on the hot path: every conv in every
/// simulated network plans here).
struct PlanEstimate {
    prep: CopyStats,
    finalize: CopyStats,
    macs: u64,
    transfer_bytes: u64,
    utilization: f64,
}

fn estimate_plan(p: &ConvParams, d: TileDims, soc: &SocConfig) -> PlanEstimate {
    let (oh, ow) = p.out_dims();
    let in_shape = Shape::nhwc(1, p.h, p.w, p.c);
    let out_shape = Shape::nhwc(1, oh, ow, p.k);
    let eb = soc.elem_bytes;
    let n_oh = ceil_div(oh, d.oh_t);
    let n_ow = ceil_div(ow, d.ow_t);
    let n_c = ceil_div(p.c, d.c_t);
    let n_k = ceil_div(p.k, d.k_t);
    let mut prep = CopyStats::default();
    let mut finalize = CopyStats::default();
    let mut transfer = 0u64;
    // Tile extents only vary at the edges: iterate the distinct extents
    // per dimension (interior + edge) with multiplicities instead of
    // every tile.
    let dim_cases = |full: usize, tile: usize| -> Vec<(usize, usize)> {
        let n = ceil_div(full, tile);
        let edge = full - (n - 1) * tile;
        if n == 1 {
            vec![(full, 1)]
        } else if edge == tile {
            vec![(tile, n)]
        } else {
            vec![(tile, n - 1), (edge, 1)]
        }
    };
    for (ohe, ohn) in dim_cases(oh, d.oh_t) {
        for (owe, own) in dim_cases(ow, d.ow_t) {
            let mult_sp = (ohn * own) as u64;
            let ih = ((ohe - 1) * p.stride + p.r).min(p.h);
            let iw = ((owe - 1) * p.stride + p.s).min(p.w);
            for (ce, cn) in dim_cases(p.c, d.c_t) {
                let r = Region::new(&[0, 0, 0, 0], &[1, ih, iw, ce]);
                let st = region_copy_stats(&in_shape, &r, eb);
                let mult = mult_sp * cn as u64;
                prep.add(CopyStats {
                    memcpys: st.memcpys * mult,
                    bytes: st.bytes * mult,
                });
                // Input + weight transfer per (spatial, c, k) item.
                transfer += mult
                    * n_k as u64
                    * ((ih * iw * ce + d.k_t.min(p.k) * p.r * p.s * ce) * eb) as u64;
            }
            for (ke, kn) in dim_cases(p.k, d.k_t) {
                let r = Region::new(&[0, 0, 0, 0], &[1, ohe, owe, ke]);
                let st = region_copy_stats(&out_shape, &r, eb);
                let mult = mult_sp * kn as u64;
                finalize.add(CopyStats {
                    memcpys: st.memcpys * mult,
                    bytes: st.bytes * mult,
                });
                transfer += mult * (ohe * owe * ke * eb) as u64;
            }
        }
    }
    let occupied_c = {
        let c_last = p.c - (n_c - 1) * d.c_t;
        ((n_c - 1) * ceil_div(d.c_t, soc.nvdla_macc_width)
            + ceil_div(c_last, soc.nvdla_macc_width))
            * soc.nvdla_macc_width
    };
    let occupied_k = {
        let k_last = p.k - (n_k - 1) * d.k_t;
        ((n_k - 1) * ceil_div(d.k_t, soc.nvdla_pes) + ceil_div(k_last, soc.nvdla_pes))
            * soc.nvdla_pes
    };
    let _ = (n_oh, n_ow);
    PlanEstimate {
        prep,
        finalize,
        macs: p.total_macs(),
        transfer_bytes: transfer,
        utilization: (p.c as f64 / occupied_c as f64) * (p.k as f64 / occupied_k as f64),
    }
}

/// Generate work items + software copy stats for a fitted tile shape.
fn build_plan(p: &ConvParams, strategy: TilingStrategy, d: TileDims, soc: &SocConfig) -> TilingPlan {
    let (oh, ow) = p.out_dims();
    let (pad_h, pad_w) = p.total_pad();
    let (pad_top, pad_left) = (pad_h / 2, pad_w / 2);
    let in_shape = Shape::nhwc(1, p.h, p.w, p.c);
    let out_shape = Shape::nhwc(1, oh, ow, p.k);
    let eb = soc.elem_bytes;

    let n_oh = ceil_div(oh, d.oh_t);
    let n_ow = ceil_div(ow, d.ow_t);
    let n_c = ceil_div(p.c, d.c_t);
    let n_k = ceil_div(p.k, d.k_t);

    let mut items = Vec::new();
    let mut prep = CopyStats::default();
    let mut finalize = CopyStats::default();
    let mut prep_tasks: Vec<CopyStats> = Vec::new();
    let mut finalize_tasks: Vec<CopyStats> = Vec::new();
    let mut group: u32 = 0;

    for kb in 0..n_k {
        let k0 = kb * d.k_t;
        let k1 = (k0 + d.k_t).min(p.k);
        for ohb in 0..n_oh {
            let oh0 = ohb * d.oh_t;
            let oh1 = (oh0 + d.oh_t).min(oh);
            for owb in 0..n_ow {
                let ow0 = owb * d.ow_t;
                let ow1 = (ow0 + d.ow_t).min(ow);
                // Input rows the output range needs (with halo), in padded
                // coordinates, then clamped to the real tensor.
                let ih0p = oh0 * p.stride;
                let ih1p = (oh1 - 1) * p.stride + p.r;
                let iw0p = ow0 * p.stride;
                let iw1p = (ow1 - 1) * p.stride + p.s;
                let ih0 = ih0p.saturating_sub(pad_top);
                let ih1 = (ih1p.saturating_sub(pad_top)).min(p.h);
                let iw0 = iw0p.saturating_sub(pad_left);
                let iw1 = (iw1p.saturating_sub(pad_left)).min(p.w);
                let pad_lo_h = pad_top.saturating_sub(ih0p);
                let pad_hi_h = (ih1p.saturating_sub(pad_top)).saturating_sub(p.h);
                let pad_lo_w = pad_left.saturating_sub(iw0p);
                let pad_hi_w = (iw1p.saturating_sub(pad_left)).saturating_sub(p.w);

                let out_region = Region::new(
                    &[0, oh0, ow0, k0],
                    &[1, oh1 - oh0, ow1 - ow0, k1 - k0],
                );
                // Finalization gathers the output tile once per group.
                let fstat = region_copy_stats(&out_shape, &out_region, eb);
                finalize.add(fstat);
                finalize_tasks.push(fstat);

                for cb in 0..n_c {
                    let c0 = cb * d.c_t;
                    let c1 = (c0 + d.c_t).min(p.c);
                    let in_region = Region::new(
                        &[0, ih0, iw0, c0],
                        &[1, ih1 - ih0, iw1 - iw0, c1 - c0],
                    );
                    // Preparation copies each input tile. Only count the
                    // copy once per (spatial, channel) block — output
                    // channel blocks reuse the same prepared tile.
                    if kb == 0 {
                        let pstat = region_copy_stats(&in_shape, &in_region, eb);
                        prep.add(pstat);
                        prep_tasks.push(pstat);
                    }
                    let m = (oh1 - oh0) * (ow1 - ow0);
                    let kdim = p.r * p.s * (c1 - c0);
                    let n = k1 - k0;
                    let last = cb == n_c - 1;
                    items.push(WorkItem {
                        in_region,
                        pad_lo: [0, pad_lo_h, pad_lo_w, 0],
                        pad_hi: [0, pad_hi_h, pad_hi_w, 0],
                        out_region: out_region.clone(),
                        c_range: (c0, c1),
                        k_range: (k0, k1),
                        reduce_group: group,
                        last_in_group: last,
                        gemm: GemmDims { m, k: kdim, n },
                        macs: (m * kdim * n) as u64,
                        in_bytes: (in_region_padded_elems(
                            ih1 - ih0 + pad_lo_h + pad_hi_h,
                            iw1 - iw0 + pad_lo_w + pad_hi_w,
                            c1 - c0,
                        ) * eb) as u64,
                        wgt_bytes: (n * kdim * eb) as u64,
                        out_bytes: if last { (m * n * eb) as u64 } else { 0 },
                    });
                }
                group += 1;
            }
        }
    }

    // Datapath lane utilization = useful lanes / occupied lanes: channel
    // blocks round up to the 32-wide MACC, output-channel blocks round up
    // to the 8 PEs; edge tiles waste lanes.
    let occupied_c = {
        let c_last = p.c - (n_c - 1) * d.c_t;
        ((n_c - 1) * ceil_div(d.c_t, soc.nvdla_macc_width)
            + ceil_div(c_last, soc.nvdla_macc_width))
            * soc.nvdla_macc_width
    };
    let occupied_k = {
        let k_last = p.k - (n_k - 1) * d.k_t;
        ((n_k - 1) * ceil_div(d.k_t, soc.nvdla_pes) + ceil_div(k_last, soc.nvdla_pes))
            * soc.nvdla_pes
    };
    let utilization =
        (p.c as f64 / occupied_c as f64) * (p.k as f64 / occupied_k as f64);

    TilingPlan {
        strategy,
        items,
        prep,
        finalize,
        prep_tasks,
        finalize_tasks,
        weight_bytes: (p.k * p.r * p.s * p.c * eb) as u64,
        num_reduce_groups: group,
        utilization,
    }
}

fn in_region_padded_elems(h: usize, w: usize, c: usize) -> usize {
    h * w * c
}

/// Rough software+compute cost in ns used to rank strategies.
fn estimate_cost(est: &PlanEstimate, soc: &SocConfig) -> f64 {
    // Software copy model (single-threaded rank heuristic): per-memcpy
    // overhead + streaming bytes. Mirrors the `cpu` model's constants so
    // the ranking matches the simulated outcome.
    let per_copy_ns = crate::cpu::PER_COPY_NS;
    let bytes_per_ns = crate::cpu::CORE_COPY_BW;
    let sw = (est.prep.memcpys + est.finalize.memcpys) as f64 * per_copy_ns
        + (est.prep.bytes + est.finalize.bytes) as f64 / bytes_per_ns;
    // Compute: MACs / (PEs * MACC width) cycles at utilization.
    let lanes = (soc.nvdla_pes * soc.nvdla_macc_width) as f64;
    let compute =
        est.macs as f64 / lanes / est.utilization.max(0.05) * soc.accel_cycle_ns();
    // Transfers at effective DRAM bandwidth.
    let xfer = est.transfer_bytes as f64 / soc.dram_eff_bytes_per_ns();
    sw + compute + xfer
}

/// Plan a convolution: enumerate candidate strategies, fit tile shapes,
/// rank by a closed-form cost estimate, and materialize only the winning
/// plan (perf: building full item lists per candidate dominated planning
/// time — see EXPERIMENTS.md §Perf).
pub fn plan_conv(p: &ConvParams, soc: &SocConfig) -> TilingPlan {
    let mut best: Option<(f64, TilingStrategy, TileDims)> = None;
    for strat in TilingStrategy::conv_candidates() {
        let Some(dims) = fit_tile(p, strat, soc) else {
            continue;
        };
        let cost = estimate_cost(&estimate_plan(p, dims, soc), soc);
        if best.as_ref().map(|(c, _, _)| cost < *c).unwrap_or(true) {
            best = Some((cost, strat, dims));
        }
    }
    let (_, strat, dims) =
        best.expect("no feasible tiling strategy — tensor too large even fully tiled");
    build_plan(p, strat, dims, soc)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn soc() -> SocConfig {
        SocConfig::default()
    }

    fn small_conv() -> ConvParams {
        ConvParams {
            h: 32,
            w: 32,
            c: 32,
            k: 32,
            r: 3,
            s: 3,
            stride: 1,
            pad_same: true,
        }
    }

    #[test]
    fn small_conv_single_or_few_tiles() {
        // 32*32*32 = 32768 elems > 16384 -> needs tiling.
        let plan = plan_conv(&small_conv(), &soc());
        assert!(!plan.items.is_empty());
        // Output coverage: union of out_regions must cover the output.
        let total_out: usize = plan
            .items
            .iter()
            .filter(|i| i.last_in_group)
            .map(|i| i.out_region.elems())
            .sum();
        assert_eq!(total_out, 32 * 32 * 32);
    }

    #[test]
    fn macs_are_preserved_by_tiling() {
        let p = small_conv();
        let plan = plan_conv(&p, &soc());
        assert_eq!(plan.total_macs(), p.total_macs());
    }

    #[test]
    fn vgg_style_layer_tiles_fit_scratchpads() {
        let p = ConvParams {
            h: 32,
            w: 32,
            c: 512,
            k: 512,
            r: 3,
            s: 3,
            stride: 1,
            pad_same: true,
        };
        let soc = soc();
        let plan = plan_conv(&p, &soc);
        for item in &plan.items {
            let in_el = item.in_region.elems();
            assert!(in_el <= soc.spad_elems(), "input tile {in_el}");
            let wgt_el = item.gemm.k * item.gemm.n;
            assert!(wgt_el <= soc.spad_elems(), "weight tile {wgt_el}");
            let out_el = item.gemm.m * item.gemm.n;
            assert!(out_el <= soc.spad_elems(), "output tile {out_el}");
        }
        assert_eq!(plan.total_macs(), p.total_macs());
    }

    #[test]
    fn strided_conv_output_dims() {
        let p = ConvParams {
            h: 224,
            w: 224,
            c: 3,
            k: 64,
            r: 7,
            s: 7,
            stride: 2,
            pad_same: true,
        };
        assert_eq!(p.out_dims(), (112, 112));
        let plan = plan_conv(&p, &soc());
        assert_eq!(plan.total_macs(), p.total_macs());
        let total_out: usize = plan
            .items
            .iter()
            .filter(|i| i.last_in_group)
            .map(|i| i.out_region.elems())
            .sum();
        assert_eq!(total_out, 112 * 112 * 64);
    }

    #[test]
    fn valid_padding_conv() {
        let p = ConvParams {
            h: 8,
            w: 8,
            c: 8,
            k: 8,
            r: 3,
            s: 3,
            stride: 1,
            pad_same: false,
        };
        assert_eq!(p.out_dims(), (6, 6));
        let plan = plan_conv(&p, &soc());
        for i in &plan.items {
            assert_eq!(i.pad_lo, [0, 0, 0, 0]);
            assert_eq!(i.pad_hi, [0, 0, 0, 0]);
        }
    }

    #[test]
    fn reduction_groups_share_output_region() {
        // Force channel tiling with a deep input.
        let p = ConvParams {
            h: 16,
            w: 16,
            c: 1024,
            k: 64,
            r: 3,
            s: 3,
            stride: 1,
            pad_same: true,
        };
        let plan = plan_conv(&p, &soc());
        let mut last_seen = std::collections::HashMap::new();
        for item in &plan.items {
            let e = last_seen
                .entry(item.reduce_group)
                .or_insert_with(|| item.out_region.clone());
            assert_eq!(*e, item.out_region, "group output mismatch");
        }
        // Exactly one last_in_group item per group.
        let lasts = plan.items.iter().filter(|i| i.last_in_group).count();
        assert_eq!(lasts as u32, plan.num_reduce_groups);
    }

    #[test]
    fn halo_padding_on_border_tiles() {
        let p = small_conv();
        let plan = plan_conv(&p, &soc());
        // SAME 3x3 conv: some tile must have top padding of 1.
        assert!(plan
            .items
            .iter()
            .any(|i| i.pad_lo[1] == 1 || i.pad_hi[1] == 1));
    }

    #[test]
    fn utilization_in_unit_range() {
        let plan = plan_conv(&small_conv(), &soc());
        assert!(plan.utilization > 0.0 && plan.utilization <= 1.0);
    }
}
