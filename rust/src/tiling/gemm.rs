//! Tiling plans for the transformer operators: batched/tall GEMMs
//! (linear projections), per-head attention GEMMs, and embedding
//! gathers.
//!
//! The plans follow the same contract as [`super::simple`]: every work
//! item fits the scratchpads, reduction groups chain contraction blocks
//! in order on one accelerator, and the per-item byte claims are exact
//! so work-conservation invariants hold across executors. The per-head
//! attention plans mirror the flash-attention tiling discipline — Q
//! tiles stay resident while K/V stream through in scratchpad-sized
//! blocks — but here only the *traffic and cycle* consequences are
//! modeled; numerics run in the reference executor.

use super::{
    region_copy_stats, CopyStats, GemmDims, Region, TilingPlan, TilingStrategy,
    WorkItem,
};
use crate::config::SocConfig;
use crate::tensor::Shape;
use crate::util::ceil_div;

/// Multi-head attention geometry shared by the score and context GEMMs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnParams {
    /// Number of attention heads.
    pub heads: usize,
    /// Query sequence length (1 for autoregressive decode).
    pub seq_q: usize,
    /// Key/value sequence length (the KV-cache length for decode).
    pub seq_kv: usize,
    /// Per-head feature dimension.
    pub d_head: usize,
}

impl AttnParams {
    /// MACs of the score GEMMs: `heads * seq_q * d_head * seq_kv`.
    pub fn score_macs(&self) -> u64 {
        (self.heads * self.seq_q * self.d_head * self.seq_kv) as u64
    }

    /// MACs of the context GEMMs: `heads * seq_q * seq_kv * d_head`.
    pub fn context_macs(&self) -> u64 {
        self.score_macs()
    }
}

/// Pick the largest PE-multiple `n` tile with `k_t * n_t <= spad`.
fn n_tile(k_t: usize, n: usize, soc: &SocConfig) -> usize {
    let n_cap = crate::runtime::CANONICAL_N[crate::runtime::CANONICAL_N.len() - 1];
    let max_n = (soc.spad_elems() / k_t).max(1).min(n_cap);
    if max_n >= soc.nvdla_pes {
        (max_n / soc.nvdla_pes) * soc.nvdla_pes
    } else {
        max_n
    }
    .min(n)
}

/// FC-style lane utilization: contraction rounds to MACC width, output
/// features to PEs.
fn gemm_utilization(k: usize, n: usize, soc: &SocConfig) -> f64 {
    let occ_k = ceil_div(k, soc.nvdla_macc_width) * soc.nvdla_macc_width;
    let occ_n = ceil_div(n, soc.nvdla_pes) * soc.nvdla_pes;
    (k as f64 / occ_k as f64) * (n as f64 / occ_n as f64)
}

/// Plan a weighted GEMM `[m, k] @ [k, n]` (transformer linear layer):
/// [`plan_fc`](super::plan_fc) generalized to `m > 1` output rows. Rows
/// tile so the input block fits the scratchpad; the contraction and
/// output features tile exactly like FC.
pub fn plan_gemm(g: &GemmDims, soc: &SocConfig) -> TilingPlan {
    let spad = soc.spad_elems();
    let eb = soc.elem_bytes;
    let k_cap = crate::runtime::CANONICAL_K[crate::runtime::CANONICAL_K.len() - 1];
    let m_cap = crate::runtime::CANONICAL_M[crate::runtime::CANONICAL_M.len() - 1];
    let k_t = g.k.min(spad).min(k_cap);
    // Rows: largest tile with an input block m_t * k_t in one scratchpad.
    let mut m_t = g.m.min(m_cap).min((spad / k_t).max(1));
    let n_t = n_tile(k_t, g.n, soc);
    // Output block m_t * n_t must also fit.
    while m_t > 1 && m_t * n_t > spad {
        m_t = ceil_div(m_t, 2);
    }
    let (n_m, n_k, n_n) = (ceil_div(g.m, m_t), ceil_div(g.k, k_t), ceil_div(g.n, n_t));

    let in_shape = Shape::nc(g.m, g.k);
    let out_shape = Shape::nc(g.m, g.n);
    let mut items = Vec::new();
    let mut prep = CopyStats::default();
    let mut finalize = CopyStats::default();
    let mut prep_tasks: Vec<CopyStats> = Vec::new();
    let mut finalize_tasks: Vec<CopyStats> = Vec::new();
    let mut group = 0u32;
    // `nb` outermost keeps the lowering's prep chunking exact: item i's
    // input block equals prep task i mod (n_m * n_k).
    for nb in 0..n_n {
        let n0 = nb * n_t;
        let n1 = (n0 + n_t).min(g.n);
        for mb in 0..n_m {
            let m0 = mb * m_t;
            let m1 = (m0 + m_t).min(g.m);
            let out_region = Region::new(&[m0, n0], &[m1 - m0, n1 - n0]);
            let fstat = region_copy_stats(&out_shape, &out_region, eb);
            finalize.add(fstat);
            finalize_tasks.push(fstat);
            for kb in 0..n_k {
                let k0 = kb * k_t;
                let k1 = (k0 + k_t).min(g.k);
                let in_region = Region::new(&[m0, k0], &[m1 - m0, k1 - k0]);
                if nb == 0 {
                    let pstat = region_copy_stats(&in_shape, &in_region, eb);
                    prep.add(pstat);
                    prep_tasks.push(pstat);
                }
                let last = kb == n_k - 1;
                let (m, k, n) = (m1 - m0, k1 - k0, n1 - n0);
                items.push(WorkItem {
                    in_region,
                    pad_lo: [0; 4],
                    pad_hi: [0; 4],
                    out_region: out_region.clone(),
                    c_range: (k0, k1),
                    k_range: (n0, n1),
                    reduce_group: group,
                    last_in_group: last,
                    gemm: GemmDims { m, k, n },
                    macs: (m * k * n) as u64,
                    in_bytes: (m * k * eb) as u64,
                    wgt_bytes: (k * n * eb) as u64,
                    out_bytes: if last { (m * n * eb) as u64 } else { 0 },
                });
            }
            group += 1;
        }
    }
    TilingPlan {
        strategy: TilingStrategy::new(false, n_k > 1, n_m > 1, false),
        items,
        prep,
        finalize,
        prep_tasks,
        finalize_tasks,
        weight_bytes: (g.k * g.n * eb) as u64,
        num_reduce_groups: group,
        utilization: gemm_utilization(g.k, g.n, soc),
    }
}

/// Plan the attention score GEMMs `scores[h] = Q[h] @ K[h]^T`: per head,
/// a Q row block stays scratchpad-resident while KV-cache key blocks
/// stream through as the weight operand — every byte of K read per step
/// is explicit accelerator traffic (the decode read side of the KV
/// cache). The contraction (`d_head`) is never tiled, so every item is
/// its own reduction group.
pub fn plan_attn_scores(p: &AttnParams, soc: &SocConfig) -> TilingPlan {
    let spad = soc.spad_elems();
    let eb = soc.elem_bytes;
    let dh = p.d_head.min(spad);
    // K blocks: kv_t keys of dh features each; Q blocks: q_t resident rows.
    let kv_t = n_tile(dh, p.seq_kv, soc);
    let mut q_t = p.seq_q.min((spad / dh).max(1));
    while q_t > 1 && q_t * kv_t > spad {
        q_t = ceil_div(q_t, 2);
    }
    let (n_q, n_kv) = (ceil_div(p.seq_q, q_t), ceil_div(p.seq_kv, kv_t));

    let q_shape = Shape::nc(p.seq_q, p.heads * p.d_head);
    let out_shape = Shape::nc(p.heads * p.seq_q, p.seq_kv);
    let mut items = Vec::new();
    let mut prep = CopyStats::default();
    let mut finalize = CopyStats::default();
    let mut prep_tasks: Vec<CopyStats> = Vec::new();
    let mut finalize_tasks: Vec<CopyStats> = Vec::new();
    let mut group = 0u32;
    let mut weight_bytes = 0u64;
    // `kvb` outermost keeps prep chunking exact: the Q tile of item i is
    // prep task i mod (heads * n_q).
    for kvb in 0..n_kv {
        let v0 = kvb * kv_t;
        let v1 = (v0 + kv_t).min(p.seq_kv);
        for h in 0..p.heads {
            for qb in 0..n_q {
                let q0 = qb * q_t;
                let q1 = (q0 + q_t).min(p.seq_q);
                let in_region =
                    Region::new(&[q0, h * p.d_head], &[q1 - q0, p.d_head]);
                if kvb == 0 {
                    let pstat = region_copy_stats(&q_shape, &in_region, eb);
                    prep.add(pstat);
                    prep_tasks.push(pstat);
                }
                let out_region =
                    Region::new(&[h * p.seq_q + q0, v0], &[q1 - q0, v1 - v0]);
                let fstat = region_copy_stats(&out_shape, &out_region, eb);
                finalize.add(fstat);
                finalize_tasks.push(fstat);
                let (m, k, n) = (q1 - q0, p.d_head, v1 - v0);
                let wgt = (k * n * eb) as u64; // K-cache block read
                weight_bytes += wgt;
                items.push(WorkItem {
                    in_region,
                    pad_lo: [0; 4],
                    pad_hi: [0; 4],
                    out_region,
                    c_range: (h * p.d_head, (h + 1) * p.d_head),
                    k_range: (v0, v1),
                    reduce_group: group,
                    last_in_group: true,
                    gemm: GemmDims { m, k, n },
                    macs: (m * k * n) as u64,
                    in_bytes: (m * k * eb) as u64,
                    wgt_bytes: wgt,
                    out_bytes: (m * n * eb) as u64,
                });
                group += 1;
            }
        }
    }
    TilingPlan {
        strategy: TilingStrategy::new(false, false, n_q > 1, n_kv > 1),
        items,
        prep,
        finalize,
        prep_tasks,
        finalize_tasks,
        weight_bytes,
        num_reduce_groups: group,
        utilization: gemm_utilization(p.d_head, p.seq_kv.min(kv_t), soc),
    }
}

/// Plan the attention context GEMMs `out[h] = P[h] @ V[h]`: per head and
/// Q block, one reduction group chains KV-cache value blocks as the
/// contraction — partial outputs accumulate in the scratchpad while V is
/// streamed (the other read side of the KV cache).
pub fn plan_attn_context(p: &AttnParams, soc: &SocConfig) -> TilingPlan {
    let spad = soc.spad_elems();
    let eb = soc.elem_bytes;
    let dh = p.d_head.min(spad);
    // V blocks: kv_t values of dh features; P blocks: q_t x kv_t probs.
    let mut kv_t = p.seq_kv.min((spad / dh).max(1));
    let mut q_t = p.seq_q.min((spad / kv_t.max(1)).max(1));
    while q_t > 1 && q_t * dh > spad {
        q_t = ceil_div(q_t, 2);
    }
    while kv_t > 1 && q_t * kv_t > spad {
        kv_t = ceil_div(kv_t, 2);
    }
    let (n_q, n_kv) = (ceil_div(p.seq_q, q_t), ceil_div(p.seq_kv, kv_t));

    let probs_shape = Shape::nc(p.heads * p.seq_q, p.seq_kv);
    let out_shape = Shape::nc(p.seq_q, p.heads * p.d_head);
    let mut items = Vec::new();
    let mut prep = CopyStats::default();
    let mut finalize = CopyStats::default();
    let mut prep_tasks: Vec<CopyStats> = Vec::new();
    let mut finalize_tasks: Vec<CopyStats> = Vec::new();
    let mut group = 0u32;
    let mut weight_bytes = 0u64;
    for h in 0..p.heads {
        for qb in 0..n_q {
            let q0 = qb * q_t;
            let q1 = (q0 + q_t).min(p.seq_q);
            let out_region =
                Region::new(&[q0, h * p.d_head], &[q1 - q0, p.d_head]);
            let fstat = region_copy_stats(&out_shape, &out_region, eb);
            finalize.add(fstat);
            finalize_tasks.push(fstat);
            for kvb in 0..n_kv {
                let v0 = kvb * kv_t;
                let v1 = (v0 + kv_t).min(p.seq_kv);
                // Probability block: rows of this head's fold, kv columns.
                let in_region =
                    Region::new(&[h * p.seq_q + q0, v0], &[q1 - q0, v1 - v0]);
                let pstat = region_copy_stats(&probs_shape, &in_region, eb);
                prep.add(pstat);
                prep_tasks.push(pstat);
                let last = kvb == n_kv - 1;
                let (m, k, n) = (q1 - q0, v1 - v0, p.d_head);
                let wgt = (k * n * eb) as u64; // V-cache block read
                weight_bytes += wgt;
                items.push(WorkItem {
                    in_region,
                    pad_lo: [0; 4],
                    pad_hi: [0; 4],
                    out_region: out_region.clone(),
                    c_range: (v0, v1),
                    k_range: (h * p.d_head, (h + 1) * p.d_head),
                    reduce_group: group,
                    last_in_group: last,
                    gemm: GemmDims { m, k, n },
                    macs: (m * k * n) as u64,
                    in_bytes: (m * k * eb) as u64,
                    wgt_bytes: wgt,
                    out_bytes: if last { (m * n * eb) as u64 } else { 0 },
                });
            }
            group += 1;
        }
    }
    TilingPlan {
        strategy: TilingStrategy::new(false, n_kv > 1, n_q > 1, false),
        items,
        prep,
        finalize,
        prep_tasks,
        finalize_tasks,
        weight_bytes,
        num_reduce_groups: group,
        utilization: gemm_utilization(p.seq_kv.min(kv_t), p.d_head, soc),
    }
}

/// Plan an embedding gather: token-id chunks sized so the gathered rows
/// fit the scratchpad. The gathered table rows are the op's weight
/// traffic — `tokens * dim` elements regardless of vocabulary size (the
/// table itself stays DRAM-resident).
pub fn plan_embedding(
    dim: usize,
    tokens: usize,
    soc: &SocConfig,
) -> TilingPlan {
    let spad = soc.spad_elems();
    let eb = soc.elem_bytes;
    let t_chunk = tokens.min((spad / dim.max(1)).max(1));
    let n_t = ceil_div(tokens, t_chunk);
    let ids_shape = Shape::nc(tokens, 1);
    let out_shape = Shape::nc(tokens, dim);
    let mut items = Vec::new();
    let mut prep = CopyStats::default();
    let mut finalize = CopyStats::default();
    let mut prep_tasks: Vec<CopyStats> = Vec::new();
    let mut finalize_tasks: Vec<CopyStats> = Vec::new();
    let mut weight_bytes = 0u64;
    for t in 0..n_t {
        let t0 = t * t_chunk;
        let t1 = (t0 + t_chunk).min(tokens);
        let in_region = Region::new(&[t0, 0], &[t1 - t0, 1]);
        let out_region = Region::new(&[t0, 0], &[t1 - t0, dim]);
        let pstat = region_copy_stats(&ids_shape, &in_region, eb);
        prep.add(pstat);
        prep_tasks.push(pstat);
        let fstat = region_copy_stats(&out_shape, &out_region, eb);
        finalize.add(fstat);
        finalize_tasks.push(fstat);
        let n_tok = t1 - t0;
        let wgt = (n_tok * dim * eb) as u64; // gathered table rows
        weight_bytes += wgt;
        items.push(WorkItem {
            in_region,
            pad_lo: [0; 4],
            pad_hi: [0; 4],
            out_region,
            c_range: (t0, t1),
            k_range: (0, dim),
            reduce_group: t as u32,
            last_in_group: true,
            gemm: GemmDims {
                m: n_tok * dim,
                k: 1,
                n: 1,
            },
            macs: (n_tok * dim) as u64,
            in_bytes: (n_tok * eb) as u64,
            wgt_bytes: wgt,
            out_bytes: (n_tok * dim * eb) as u64,
        });
    }
    TilingPlan {
        strategy: if n_t > 1 {
            TilingStrategy::new(false, false, true, false)
        } else {
            TilingStrategy::NONE
        },
        items,
        prep,
        finalize,
        prep_tasks,
        finalize_tasks,
        weight_bytes,
        num_reduce_groups: n_t as u32,
        utilization: 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn soc() -> SocConfig {
        SocConfig::default()
    }

    fn check_spad(plan: &TilingPlan) {
        let spad = soc().spad_elems();
        for i in &plan.items {
            assert!(i.gemm.m * i.gemm.k <= spad, "input tile too big: {i:?}");
            assert!(i.gemm.k * i.gemm.n <= spad, "weight tile too big: {i:?}");
            assert!(i.gemm.m * i.gemm.n <= spad, "output tile too big: {i:?}");
        }
    }

    #[test]
    fn gemm_plan_covers_all_macs() {
        let g = GemmDims { m: 128, k: 128, n: 512 };
        let plan = plan_gemm(&g, &soc());
        assert_eq!(plan.total_macs(), (g.m * g.k * g.n) as u64);
        check_spad(&plan);
        // Row blocks write the full output exactly once.
        let out: u64 = plan.items.iter().map(|i| i.out_bytes).sum();
        assert_eq!(out, (g.m * g.n * soc().elem_bytes) as u64);
    }

    #[test]
    fn gemm_prep_chunking_is_exact() {
        // The IR chunks prep when items[i].in_region ==
        // prep_tasks[i % n_prep]'s region; the nb-outermost loop order
        // guarantees it.
        let g = GemmDims { m: 512, k: 768, n: 768 };
        let plan = plan_gemm(&g, &soc());
        let n_prep = plan.prep_tasks.len();
        assert!(n_prep > 0 && plan.items.len() % n_prep == 0);
        for (i, item) in plan.items.iter().enumerate() {
            assert_eq!(item.in_region, plan.items[i % n_prep].in_region);
        }
    }

    #[test]
    fn attn_scores_decode_reads_whole_k_cache() {
        // Decode: one query token against a 512-entry KV cache. The K
        // bytes streamed must equal the whole per-head cache, every step.
        let p = AttnParams { heads: 4, seq_q: 1, seq_kv: 512, d_head: 64 };
        let plan = plan_attn_scores(&p, &soc());
        let kv_read: u64 = plan.items.iter().map(|i| i.wgt_bytes).sum();
        assert_eq!(
            kv_read,
            (p.heads * p.seq_kv * p.d_head * soc().elem_bytes) as u64
        );
        assert_eq!(plan.total_macs(), p.score_macs());
        check_spad(&plan);
    }

    #[test]
    fn attn_context_chains_kv_blocks_per_group() {
        let p = AttnParams { heads: 2, seq_q: 128, seq_kv: 512, d_head: 64 };
        let plan = plan_attn_context(&p, &soc());
        assert_eq!(plan.total_macs(), p.context_macs());
        check_spad(&plan);
        // Each group ends with exactly one write-back.
        let writes = plan.items.iter().filter(|i| i.last_in_group).count();
        assert_eq!(writes as u32, plan.num_reduce_groups);
        // Every (head, q-block) group streams the whole per-head V slice.
        let v_read: u64 = plan.items.iter().map(|i| i.wgt_bytes).sum();
        assert_eq!(
            v_read,
            plan.num_reduce_groups as u64
                * (p.seq_kv * p.d_head * soc().elem_bytes) as u64
        );
    }

    #[test]
    fn embedding_gathers_exactly_tokens_times_dim() {
        let plan = plan_embedding(128, 384, &soc());
        let gathered: u64 = plan.items.iter().map(|i| i.wgt_bytes).sum();
        assert_eq!(gathered, (384 * 128 * soc().elem_bytes) as u64);
        assert_eq!(plan.weight_bytes, gathered);
        let out: usize = plan.items.iter().map(|i| i.out_region.elems()).sum();
        assert_eq!(out, 384 * 128);
    }
}
