//! Tiling plans for the non-convolution operators: inner products (FC),
//! pooling, and element-wise/batch-norm ops.

use super::{
    region_copy_stats, CopyStats, GemmDims, Region, TilingPlan, TilingStrategy,
    WorkItem,
};
use crate::config::SocConfig;
use crate::tensor::Shape;
use crate::util::ceil_div;

/// Inner-product (fully-connected) parameters, single batch.
#[derive(Debug, Clone, Copy)]
pub struct FcParams {
    /// Input features.
    pub c_in: usize,
    /// Output features.
    pub c_out: usize,
}

impl FcParams {
    /// Total MACs.
    pub fn total_macs(&self) -> u64 {
        (self.c_in * self.c_out) as u64
    }
}

/// Plan an inner product: GEMM with m=1; tile the contraction (input
/// features) and the output features to fit the scratchpads.
pub fn plan_fc(p: &FcParams, soc: &SocConfig) -> TilingPlan {
    let spad = soc.spad_elems();
    let eb = soc.elem_bytes;
    // Input tile: k_t elements; weight tile: k_t * n_t; output tile: n_t.
    // The contraction depth is additionally capped by the GEMM descriptor
    // limit (canonical artifact grid).
    let k_cap = crate::runtime::CANONICAL_K[crate::runtime::CANONICAL_K.len() - 1];
    let n_cap = crate::runtime::CANONICAL_N[crate::runtime::CANONICAL_N.len() - 1];
    let k_t = p.c_in.min(spad).min(k_cap);
    // Choose n_t as the largest PE multiple with k_t * n_t <= spad.
    let max_n = (spad / k_t).max(1).min(n_cap);
    let n_t = if max_n >= soc.nvdla_pes {
        (max_n / soc.nvdla_pes) * soc.nvdla_pes
    } else {
        max_n
    }
    .min(p.c_out);
    let n_k = ceil_div(p.c_in, k_t);
    let n_n = ceil_div(p.c_out, n_t);

    let in_shape = Shape::nc(1, p.c_in);
    let out_shape = Shape::nc(1, p.c_out);
    let mut items = Vec::new();
    let mut prep = CopyStats::default();
    let mut finalize = CopyStats::default();
    let mut prep_tasks: Vec<CopyStats> = Vec::new();
    let mut finalize_tasks: Vec<CopyStats> = Vec::new();
    let mut group = 0u32;
    for nb in 0..n_n {
        let n0 = nb * n_t;
        let n1 = (n0 + n_t).min(p.c_out);
        let out_region = Region::new(&[0, n0], &[1, n1 - n0]);
        let fstat = region_copy_stats(&out_shape, &out_region, eb);
        finalize.add(fstat);
        finalize_tasks.push(fstat);
        for kb in 0..n_k {
            let k0 = kb * k_t;
            let k1 = (k0 + k_t).min(p.c_in);
            let in_region = Region::new(&[0, k0], &[1, k1 - k0]);
            if nb == 0 {
                let pstat = region_copy_stats(&in_shape, &in_region, eb);
                prep.add(pstat);
                prep_tasks.push(pstat);
            }
            let last = kb == n_k - 1;
            let (m, k, n) = (1, k1 - k0, n1 - n0);
            items.push(WorkItem {
                in_region,
                pad_lo: [0; 4],
                pad_hi: [0; 4],
                out_region: out_region.clone(),
                c_range: (k0, k1),
                k_range: (n0, n1),
                reduce_group: group,
                last_in_group: last,
                gemm: GemmDims { m, k, n },
                macs: (k * n) as u64,
                in_bytes: (k * eb) as u64,
                wgt_bytes: (k * n * eb) as u64,
                out_bytes: if last { (n * eb) as u64 } else { 0 },
            });
        }
        group += 1;
    }
    // Lane utilization: FC engages one output pixel; channel blocks round
    // to MACC width, output features to PEs.
    let occ_k = ceil_div(p.c_in, soc.nvdla_macc_width) * soc.nvdla_macc_width;
    let occ_n = ceil_div(p.c_out, soc.nvdla_pes) * soc.nvdla_pes;
    TilingPlan {
        strategy: TilingStrategy::new(false, true, false, false),
        items,
        prep,
        finalize,
        prep_tasks,
        finalize_tasks,
        weight_bytes: (p.c_in * p.c_out * eb) as u64,
        num_reduce_groups: group,
        utilization: (p.c_in as f64 / occ_k as f64) * (p.c_out as f64 / occ_n as f64),
    }
}

/// Pooling parameters (square window).
#[derive(Debug, Clone, Copy)]
pub struct PoolParams {
    /// Input rows.
    pub h: usize,
    /// Input cols.
    pub w: usize,
    /// Channels.
    pub c: usize,
    /// Window size.
    pub size: usize,
    /// Stride.
    pub stride: usize,
}

impl PoolParams {
    /// Output spatial dims (VALID semantics).
    pub fn out_dims(&self) -> (usize, usize) {
        (
            (self.h - self.size) / self.stride + 1,
            (self.w - self.size) / self.stride + 1,
        )
    }
}

/// Plan a pooling operator: row-wise spatial tiling, channels kept whole
/// (element-wise in channels; tiling strategy barely matters — paper §II-B).
pub fn plan_pool(p: &PoolParams, soc: &SocConfig) -> TilingPlan {
    let spad = soc.spad_elems();
    let eb = soc.elem_bytes;
    let (oh, ow) = p.out_dims();
    // Shrink output rows, then cols, then channels until the input tile
    // (with window halo) fits the scratchpad.
    let (mut oh_t, mut ow_t, mut c_t) = (oh, ow, p.c);
    let in_elems = |oh_t: usize, ow_t: usize, c_t: usize| {
        ((oh_t - 1) * p.stride + p.size) * ((ow_t - 1) * p.stride + p.size) * c_t
    };
    while in_elems(oh_t, ow_t, c_t) > spad {
        if oh_t > 1 {
            oh_t = ceil_div(oh_t, 2);
        } else if ow_t > 1 {
            ow_t = ceil_div(ow_t, 2);
        } else if c_t > 1 {
            c_t = ceil_div(c_t, 2);
        } else {
            break; // degenerate: single window; accept
        }
    }
    let in_shape = Shape::nhwc(1, p.h, p.w, p.c);
    let out_shape = Shape::nhwc(1, oh, ow, p.c);
    let (n_h, n_w, n_c) = (ceil_div(oh, oh_t), ceil_div(ow, ow_t), ceil_div(p.c, c_t));
    let mut items = Vec::new();
    let mut prep = CopyStats::default();
    let mut finalize = CopyStats::default();
    let mut prep_tasks: Vec<CopyStats> = Vec::new();
    let mut finalize_tasks: Vec<CopyStats> = Vec::new();
    let mut group = 0u32;
    for hb in 0..n_h {
        let o0 = hb * oh_t;
        let o1 = (o0 + oh_t).min(oh);
        let i0 = o0 * p.stride;
        let i1 = ((o1 - 1) * p.stride + p.size).min(p.h);
        for wb in 0..n_w {
            let q0 = wb * ow_t;
            let q1 = (q0 + ow_t).min(ow);
            let j0 = q0 * p.stride;
            let j1 = ((q1 - 1) * p.stride + p.size).min(p.w);
            for cb in 0..n_c {
                let c0 = cb * c_t;
                let c1 = (c0 + c_t).min(p.c);
                let in_region =
                    Region::new(&[0, i0, j0, c0], &[1, i1 - i0, j1 - j0, c1 - c0]);
                let out_region =
                    Region::new(&[0, o0, q0, c0], &[1, o1 - o0, q1 - q0, c1 - c0]);
                let pstat = region_copy_stats(&in_shape, &in_region, eb);
                let fstat = region_copy_stats(&out_shape, &out_region, eb);
                prep.add(pstat);
                prep_tasks.push(pstat);
                finalize.add(fstat);
                finalize_tasks.push(fstat);
                let out_elems = out_region.elems();
                items.push(WorkItem {
                    in_region: in_region.clone(),
                    pad_lo: [0; 4],
                    pad_hi: [0; 4],
                    out_region,
                    c_range: (c0, c1),
                    k_range: (c0, c1),
                    reduce_group: group,
                    last_in_group: true,
                    gemm: GemmDims {
                        m: out_elems,
                        k: p.size * p.size,
                        n: 1,
                    },
                    macs: (out_elems * p.size * p.size) as u64,
                    in_bytes: (in_region.elems() * eb) as u64,
                    wgt_bytes: 0,
                    out_bytes: (out_elems * eb) as u64,
                });
                group += 1;
            }
        }
    }
    TilingPlan {
        strategy: TilingStrategy::new(false, n_c > 1, true, n_w > 1),
        items,
        prep,
        finalize,
        prep_tasks,
        finalize_tasks,
        weight_bytes: 0,
        num_reduce_groups: group,
        utilization: 1.0,
    }
}

/// Plan an element-wise operator (add / BN / activation) over `elems`
/// elements with `n_inputs` operand tensors: flat chunking, one long
/// contiguous memcpy per chunk (tiling strategy is irrelevant for
/// element-wise ops — paper §II-B).
pub fn plan_eltwise(elems: usize, n_inputs: usize, soc: &SocConfig) -> TilingPlan {
    let spad = soc.spad_elems();
    let eb = soc.elem_bytes;
    let chunk = spad.min(elems);
    let n_t = ceil_div(elems, chunk);
    let shape = Shape::nc(1, elems);
    let mut items = Vec::new();
    let mut prep = CopyStats::default();
    let mut finalize = CopyStats::default();
    let mut prep_tasks: Vec<CopyStats> = Vec::new();
    let mut finalize_tasks: Vec<CopyStats> = Vec::new();
    for t in 0..n_t {
        let e0 = t * chunk;
        let e1 = (e0 + chunk).min(elems);
        let region = Region::new(&[0, e0], &[1, e1 - e0]);
        let pstat = region_copy_stats(&shape, &region, eb);
        for _ in 0..n_inputs {
            prep.add(pstat);
        }
        prep_tasks.push(CopyStats {
            memcpys: pstat.memcpys * n_inputs as u64,
            bytes: pstat.bytes * n_inputs as u64,
        });
        let fstat = region_copy_stats(&shape, &region, eb);
        finalize.add(fstat);
        finalize_tasks.push(fstat);
        let n_el = e1 - e0;
        items.push(WorkItem {
            in_region: region.clone(),
            pad_lo: [0; 4],
            pad_hi: [0; 4],
            out_region: region,
            c_range: (e0, e1),
            k_range: (e0, e1),
            reduce_group: t as u32,
            last_in_group: true,
            gemm: GemmDims { m: n_el, k: 1, n: 1 },
            macs: n_el as u64,
            in_bytes: (n_el * n_inputs * eb) as u64,
            wgt_bytes: 0,
            out_bytes: (n_el * eb) as u64,
        });
    }
    TilingPlan {
        strategy: TilingStrategy::NONE,
        items,
        prep,
        finalize,
        prep_tasks,
        finalize_tasks,
        weight_bytes: 0,
        num_reduce_groups: n_t as u32,
        utilization: 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn soc() -> SocConfig {
        SocConfig::default()
    }

    #[test]
    fn fc_plan_covers_all_macs() {
        let p = FcParams { c_in: 784, c_out: 256 };
        let plan = plan_fc(&p, &soc());
        assert_eq!(plan.total_macs(), p.total_macs());
        assert!(plan.utilization > 0.5);
    }

    #[test]
    fn fc_large_layer_is_reduced() {
        // ResNet50 FC: 2048 -> 1000; weight 2M elems >> 16K spad.
        let p = FcParams { c_in: 2048, c_out: 1000 };
        let plan = plan_fc(&p, &soc());
        assert!(plan.items.len() > 100);
        assert_eq!(plan.total_macs(), p.total_macs());
        for i in &plan.items {
            assert!(i.gemm.k * i.gemm.n <= soc().spad_elems());
        }
    }

    #[test]
    fn pool_plan_out_dims_and_coverage() {
        let p = PoolParams { h: 32, w: 32, c: 64, size: 2, stride: 2 };
        assert_eq!(p.out_dims(), (16, 16));
        let plan = plan_pool(&p, &soc());
        let out: usize = plan.items.iter().map(|i| i.out_region.elems()).sum();
        assert_eq!(out, 16 * 16 * 64);
    }

    #[test]
    fn pool_tiles_fit_spad() {
        let p = PoolParams { h: 64, w: 64, c: 512, size: 2, stride: 2 };
        let plan = plan_pool(&p, &soc());
        for i in &plan.items {
            assert!(i.in_region.elems() <= soc().spad_elems());
        }
        assert!(plan.items.len() > 1);
    }

    #[test]
    fn eltwise_chunks_cover_everything() {
        let plan = plan_eltwise(100_000, 2, &soc());
        let total: usize = plan.items.iter().map(|i| i.out_region.elems()).sum();
        assert_eq!(total, 100_000);
        // Two operands double the prep copies.
        assert_eq!(plan.prep.memcpys, 2 * plan.finalize.memcpys);
    }

    #[test]
    fn eltwise_single_chunk_small() {
        let plan = plan_eltwise(100, 1, &soc());
        assert_eq!(plan.items.len(), 1);
        assert_eq!(plan.prep.memcpys, 1);
    }
}
