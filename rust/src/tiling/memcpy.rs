//! Memcpy accounting for tiling/untiling (paper Fig 5/6).
//!
//! Tiling copies non-contiguous logical regions of a tensor into contiguous
//! smaller tensors; the cost is dominated by *how many* contiguous runs the
//! copy decomposes into. An NHWC tensor tiled channel-wise produces many
//! short runs (channels are innermost); tiled row-wise it produces few long
//! runs — the paper measures 1.78x / 6.5x differences from exactly this.

use crate::tensor::{Shape, Tensor};

/// A rectangular region of a tensor (offsets + extents per dimension).
///
/// Stored as fixed 4-wide arrays plus a rank (regions are created per
/// accelerator work item on the planning hot path; heap-free construction
/// measurably speeds up whole-network simulation — EXPERIMENTS.md §Perf).
/// Unused trailing dimensions hold offset 0 / extent 1.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Region {
    /// Start offset per dimension (first `rank` entries meaningful).
    pub off: [usize; 4],
    /// Extent per dimension (first `rank` entries meaningful).
    pub shape: [usize; 4],
    rank: u8,
}

impl Region {
    /// Region covering an entire shape.
    pub fn full(shape: &Shape) -> Self {
        Self::new(&[0; 4][..shape.rank()], shape.dims())
    }

    /// Region with explicit offsets and extents.
    pub fn new(off: &[usize], shape: &[usize]) -> Self {
        assert_eq!(off.len(), shape.len());
        assert!(!shape.is_empty() && shape.len() <= 4);
        let mut o = [0usize; 4];
        let mut s = [1usize; 4];
        o[..off.len()].copy_from_slice(off);
        s[..shape.len()].copy_from_slice(shape);
        Self {
            off: o,
            shape: s,
            rank: off.len() as u8,
        }
    }

    /// Number of meaningful dimensions.
    pub fn rank(&self) -> usize {
        self.rank as usize
    }

    /// Total elements in the region.
    pub fn elems(&self) -> usize {
        self.shape[..self.rank()].iter().product()
    }

    /// True if this region and `other` (same rank, same coordinate
    /// space) overlap in every dimension. Regions of different ranks
    /// never intersect — callers comparing tiles across operators must
    /// fall back to a conservative whole-tensor dependency instead.
    pub fn intersects(&self, other: &Region) -> bool {
        self.rank == other.rank
            && (0..self.rank()).all(|d| {
                self.off[d] < other.off[d] + other.shape[d]
                    && other.off[d] < self.off[d] + self.shape[d]
            })
    }

    /// True if the region stays within `bounds`.
    pub fn fits_in(&self, bounds: &Shape) -> bool {
        self.rank() == bounds.rank()
            && self.off[..self.rank()]
                .iter()
                .zip(&self.shape[..self.rank()])
                .zip(bounds.dims())
                .all(|((&o, &s), &b)| o + s <= b)
    }
}

/// Aggregate memcpy statistics for a data-movement phase.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CopyStats {
    /// Number of contiguous memcpy calls.
    pub memcpys: u64,
    /// Total bytes moved.
    pub bytes: u64,
}

impl CopyStats {
    /// Accumulate another stats value.
    pub fn add(&mut self, other: CopyStats) {
        self.memcpys += other.memcpys;
        self.bytes += other.bytes;
    }

    /// Average contiguous chunk size in bytes (0 if no copies).
    pub fn avg_chunk_bytes(&self) -> f64 {
        if self.memcpys == 0 {
            0.0
        } else {
            self.bytes as f64 / self.memcpys as f64
        }
    }
}

/// Memcpy statistics for copying `region` out of (or into) a row-major
/// tensor of shape `src`: the number of contiguous runs and bytes moved.
///
/// The contiguous run length is the product of the innermost dimensions the
/// region covers *fully*, times the region extent of the first partially
/// covered dimension; every outer region dimension multiplies the run
/// count.
pub fn region_copy_stats(src: &Shape, region: &Region, elem_bytes: usize) -> CopyStats {
    assert!(region.fits_in(src), "region {region:?} outside {src}");
    let rank = src.rank();
    // Find the first dimension (from innermost) that is not fully covered.
    let mut chunk = 1usize; // elements per contiguous run
    let mut split = rank; // dims [0, split) contribute to run count
    for d in (0..rank).rev() {
        if region.shape[d] == src.dim(d) {
            chunk *= src.dim(d);
        } else {
            chunk *= region.shape[d];
            split = d;
            break;
        }
    }
    if split == rank {
        // Entire tensor: single memcpy.
        return CopyStats {
            memcpys: 1,
            bytes: (region.elems() * elem_bytes) as u64,
        };
    }
    let runs: usize = region.shape[..split].iter().product();
    CopyStats {
        memcpys: runs as u64,
        bytes: (runs * chunk * elem_bytes) as u64,
    }
}

/// Functionally extract `region` from `src` into a dense buffer, with
/// `pad_lo`/`pad_hi` zero-padding per dimension (for conv halos that fall
/// outside the tensor). Returns the padded, dense tile data.
pub fn extract_region_padded(
    src: &Tensor,
    region: &Region,
    pad_lo: &[usize],
    pad_hi: &[usize],
) -> Vec<f32> {
    let rank = src.desc.shape.rank();
    assert_eq!(region.rank(), rank);
    let out_dims: Vec<usize> = (0..rank)
        .map(|d| pad_lo[d] + region.shape[d] + pad_hi[d])
        .collect();
    let out_elems: usize = out_dims.iter().product();
    let mut out = vec![0.0f32; out_elems];
    let src_strides = src.desc.shape.strides();
    let mut out_strides = vec![1usize; rank];
    for i in (0..rank.saturating_sub(1)).rev() {
        out_strides[i] = out_strides[i + 1] * out_dims[i + 1];
    }
    // Iterate all but the innermost dimension; copy innermost runs.
    let inner = rank - 1;
    let run = region.shape[inner];
    let outer_count: usize = region.shape[..inner].iter().product();
    let mut idx = vec![0usize; inner];
    for _ in 0..outer_count {
        let mut s_off = 0usize;
        let mut d_off = pad_lo[inner];
        for d in 0..inner {
            s_off += (region.off[d] + idx[d]) * src_strides[d];
            d_off += (pad_lo[d] + idx[d]) * out_strides[d];
        }
        s_off += region.off[inner];
        out[d_off..d_off + run]
            .copy_from_slice(&src.data[s_off..s_off + run]);
        // Increment multi-index.
        for d in (0..inner).rev() {
            idx[d] += 1;
            if idx[d] < region.shape[d] {
                break;
            }
            idx[d] = 0;
        }
    }
    out
}

/// Functionally scatter dense `tile` data into `region` of `dst`
/// (the "untiling"/data-finalization operation).
pub fn insert_region(dst: &mut Tensor, region: &Region, tile: &[f32]) {
    let rank = dst.desc.shape.rank();
    assert_eq!(region.elems(), tile.len(), "tile size mismatch");
    let dst_strides = dst.desc.shape.strides();
    let inner = rank - 1;
    let run = region.shape[inner];
    let outer_count: usize = region.shape[..inner].iter().product();
    let mut idx = vec![0usize; inner];
    let mut t_off = 0usize;
    for _ in 0..outer_count {
        let mut d_off = 0usize;
        for d in 0..inner {
            d_off += (region.off[d] + idx[d]) * dst_strides[d];
        }
        d_off += region.off[inner];
        dst.data[d_off..d_off + run].copy_from_slice(&tile[t_off..t_off + run]);
        t_off += run;
        for d in (0..inner).rev() {
            idx[d] += 1;
            if idx[d] < region.shape[d] {
                break;
            }
            idx[d] = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::TensorDesc;

    #[test]
    fn paper_fig6_medium_tensor_counts() {
        // 1x16x16x128 NHWC, max tile 16384 elems (paper Fig 6).
        let s = Shape::nhwc(1, 16, 16, 128);
        // Channel-wise tile 1x16x16x64: 16*16=256 runs of 64 elems per tile;
        // two tiles cover the tensor -> 512 memcpys of 64 elements.
        let ch = Region::new(&[0, 0, 0, 0], &[1, 16, 16, 64]);
        let st = region_copy_stats(&s, &ch, 2);
        assert_eq!(st.memcpys, 256);
        assert_eq!(st.bytes, 256 * 64 * 2);
        // Row-wise tile 1x8x16x128: one contiguous 8*16*128=16K-elem run.
        let row = Region::new(&[0, 0, 0, 0], &[1, 8, 16, 128]);
        let st = region_copy_stats(&s, &row, 2);
        assert_eq!(st.memcpys, 1);
        assert_eq!(st.bytes, 16384 * 2);
    }

    #[test]
    fn paper_fig6_large_tensor_counts() {
        // 1x64x64x512: DimHW tile 1x1x32x512 -> 1 run of 16K elems;
        // DimCH tile 1x32x64x8 -> 32*64=2048 runs of 8 elems.
        let s = Shape::nhwc(1, 64, 64, 512);
        let hw = Region::new(&[0, 0, 0, 0], &[1, 1, 32, 512]);
        assert_eq!(region_copy_stats(&s, &hw, 2).memcpys, 1);
        let ch = Region::new(&[0, 0, 0, 0], &[1, 32, 64, 8]);
        assert_eq!(region_copy_stats(&s, &ch, 2).memcpys, 2048);
    }

    #[test]
    fn region_intersection_is_per_dimension() {
        let a = Region::new(&[0, 0, 0, 0], &[1, 4, 4, 8]);
        let b = Region::new(&[0, 3, 3, 0], &[1, 4, 4, 8]);
        let c = Region::new(&[0, 4, 0, 0], &[1, 4, 4, 8]);
        let d = Region::new(&[0, 0, 0, 8], &[1, 4, 4, 8]);
        assert!(a.intersects(&b) && b.intersects(&a));
        assert!(!a.intersects(&c), "touching edges do not overlap");
        assert!(!a.intersects(&d), "disjoint channel ranges");
        // Rank mismatch never intersects (different coordinate spaces).
        let flat = Region::new(&[0, 0], &[1, 128]);
        assert!(!a.intersects(&flat));
        assert!(flat.intersects(&Region::new(&[0, 100], &[1, 50])));
    }

    #[test]
    fn full_region_is_one_memcpy() {
        let s = Shape::nhwc(2, 4, 4, 8);
        let st = region_copy_stats(&s, &Region::full(&s), 2);
        assert_eq!(st.memcpys, 1);
        assert_eq!(st.bytes, 2 * 4 * 4 * 8 * 2);
    }

    #[test]
    #[should_panic(expected = "outside")]
    fn out_of_bounds_region_panics() {
        let s = Shape::nhwc(1, 4, 4, 4);
        region_copy_stats(&s, &Region::new(&[0, 2, 0, 0], &[1, 4, 4, 4]), 2);
    }

    #[test]
    fn extract_insert_roundtrip() {
        let d = TensorDesc::nhwc16(1, 4, 4, 3);
        let data: Vec<f32> = (0..48).map(|i| i as f32).collect();
        let t = Tensor::from_data(d.clone(), data);
        let r = Region::new(&[0, 1, 1, 0], &[1, 2, 2, 3]);
        let tile = extract_region_padded(&t, &r, &[0; 4], &[0; 4]);
        assert_eq!(tile.len(), 12);
        // First run = elements at (0,1,1,0..3) = indices 15,16,17.
        assert_eq!(&tile[0..3], &[15.0, 16.0, 17.0]);
        let mut dst = Tensor::zeros(d);
        insert_region(&mut dst, &r, &tile);
        assert_eq!(dst.at4(0, 1, 1, 0), 15.0);
        assert_eq!(dst.at4(0, 2, 2, 2), 32.0);
        assert_eq!(dst.at4(0, 0, 0, 0), 0.0);
    }

    #[test]
    fn extract_with_padding_zero_fills_halo() {
        let d = TensorDesc::nhwc16(1, 2, 2, 1);
        let t = Tensor::from_data(d, vec![1.0, 2.0, 3.0, 4.0]);
        let r = Region::full(&t.desc.shape);
        let tile = extract_region_padded(&t, &r, &[0, 1, 1, 0], &[0, 1, 1, 0]);
        // Padded to 1x4x4x1 with the 2x2 payload centered.
        assert_eq!(tile.len(), 16);
        assert_eq!(tile[5], 1.0);
        assert_eq!(tile[6], 2.0);
        assert_eq!(tile[9], 3.0);
        assert_eq!(tile[10], 4.0);
        assert_eq!(tile[0], 0.0);
        assert_eq!(tile[15], 0.0);
    }

    #[test]
    fn copy_stats_accumulate() {
        let mut a = CopyStats::default();
        a.add(CopyStats { memcpys: 3, bytes: 30 });
        a.add(CopyStats { memcpys: 2, bytes: 20 });
        assert_eq!(a.memcpys, 5);
        assert_eq!(a.bytes, 50);
        assert_eq!(a.avg_chunk_bytes(), 10.0);
    }
}
