//! Per-operator records and end-to-end reports (Fig 1 / 12 / 15 / 18).

use crate::energy::EnergyAccount;
use crate::mem::MemsysSnapshot;
use crate::util::{fmt_bytes, fmt_ns, fmt_pj};

/// Timing/traffic record for one operator.
#[derive(Debug, Clone, Default)]
pub struct OpRecord {
    /// Operator name.
    pub name: String,
    /// Kind tag (C/P/F/B/E/...).
    pub tag: String,
    /// Tiling strategy chosen.
    pub strategy: String,
    /// Wall start (ns).
    pub start_ns: f64,
    /// Wall end (ns).
    pub end_ns: f64,
    /// Accelerator-compute component (critical-path attribution), ns.
    pub accel_ns: f64,
    /// Data-transfer component (incl. DMA coherency management), ns.
    pub transfer_ns: f64,
    /// CPU data preparation (layout transform + tiling), ns.
    pub prep_ns: f64,
    /// CPU data finalization (untiling), ns.
    pub finalize_ns: f64,
    /// Other CPU software time (dispatch, tracking, sync), ns.
    pub other_ns: f64,
    /// Number of accelerator work items.
    pub tiles: usize,
    /// Independent reduction groups (max tile-level parallelism).
    pub reduce_groups: u32,
    /// MACs executed.
    pub macs: u64,
    /// DRAM bytes moved for this op.
    pub dram_bytes: u64,
}

impl OpRecord {
    /// Wall duration of the op.
    pub fn span_ns(&self) -> f64 {
        self.end_ns - self.start_ns
    }
}

/// End-to-end latency breakdown (paper Fig 1's three components, with the
/// software stack further split as in Fig 15).
#[derive(Debug, Clone, Copy, Default)]
pub struct Breakdown {
    /// Accelerator compute, ns.
    pub accel_ns: f64,
    /// Data transfer (payload + coherency management), ns.
    pub transfer_ns: f64,
    /// CPU data preparation, ns.
    pub prep_ns: f64,
    /// CPU data finalization, ns.
    pub finalize_ns: f64,
    /// Other CPU software, ns.
    pub other_ns: f64,
}

impl Breakdown {
    /// Accumulate one operator record's components.
    pub fn add_record(&mut self, r: &OpRecord) {
        self.accel_ns += r.accel_ns;
        self.transfer_ns += r.transfer_ns;
        self.prep_ns += r.prep_ns;
        self.finalize_ns += r.finalize_ns;
        self.other_ns += r.other_ns;
    }

    /// Total of all components.
    pub fn total_ns(&self) -> f64 {
        self.accel_ns + self.transfer_ns + self.cpu_ns()
    }

    /// Total CPU software-stack time.
    pub fn cpu_ns(&self) -> f64 {
        self.prep_ns + self.finalize_ns + self.other_ns
    }

    /// Fractions (accel, transfer, cpu) of total.
    pub fn fractions(&self) -> (f64, f64, f64) {
        let t = self.total_ns().max(1e-12);
        (
            self.accel_ns / t,
            self.transfer_ns / t,
            self.cpu_ns() / t,
        )
    }
}

/// How much of the workload's serialized work the schedule hid, plus
/// per-resource occupancy — the `pipeline` section of the unified
/// report. A strict serial schedule has `overlap_frac ~ 0`; cross-op
/// tile pipelining pushes it toward the accelerator-idle fraction the
/// paper's Fig 1 exposes.
#[derive(Debug, Clone, Default)]
pub struct PipelineStats {
    /// Granularity the event engine ran: `serial`, `op`, or `tile`.
    pub mode: String,
    /// `1 - makespan / sum-of-components`: the fraction of serialized
    /// work hidden by overlap (0 when nothing overlaps).
    pub overlap_frac: f64,
    /// CPU software-stack busy fraction of the makespan.
    pub cpu_occupancy: f64,
    /// Datapath busy fraction of the makespan, one entry per pool slot.
    pub accel_occupancy: Vec<f64>,
    /// Mean DRAM bandwidth utilization over the makespan.
    pub dram_utilization: f64,
}

/// Complete simulation report for one forward pass.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    /// Network name.
    pub network: String,
    /// Configuration description (accels/interface/threads).
    pub config: String,
    /// End-to-end latency, ns.
    pub total_ns: f64,
    /// Component breakdown.
    pub breakdown: Breakdown,
    /// Per-op records in execution order.
    pub ops: Vec<OpRecord>,
    /// Total DRAM traffic, bytes.
    pub dram_bytes: u64,
    /// Total LLC traffic, bytes.
    pub llc_bytes: u64,
    /// Mean DRAM bandwidth utilization over the run.
    pub dram_utilization: f64,
    /// Mean DRAM bandwidth utilization during prep/finalize phases only
    /// (Fig 17's metric).
    pub sw_phase_dram_utilization: f64,
    /// Energy account.
    pub energy: EnergyAccount,
    /// Overlap fraction + per-resource occupancy for the schedule that
    /// produced this report.
    pub pipeline: PipelineStats,
    /// Routed memory-system snapshot: per-channel and per-link traffic
    /// and occupancy over the run.
    pub memsys: MemsysSnapshot,
    /// Host wall-clock spent simulating, ns (Fig 10's metric).
    pub sim_wallclock_ns: f64,
}

impl SimReport {
    /// Fig-1-style one-line row: components as % of total.
    pub fn breakdown_row(&self) -> String {
        let (a, t, c) = self.breakdown.fractions();
        format!(
            "{:<10} total {:>12}  accel {:>5.1}%  transfer {:>5.1}%  cpu {:>5.1}%",
            self.network,
            fmt_ns(self.total_ns),
            a * 100.0,
            t * 100.0,
            c * 100.0
        )
    }

    /// Multi-line human-readable report.
    pub fn breakdown_table(&self) -> String {
        let b = &self.breakdown;
        format!(
            "network   : {}\nconfig    : {}\nlatency   : {}\n  accel compute  : {} ({:.1}%)\n  data transfer  : {} ({:.1}%)\n  data prep      : {} ({:.1}%)\n  data finalize  : {} ({:.1}%)\n  other software : {} ({:.1}%)\ndram traffic : {}\nllc traffic  : {}\ndram util    : {:.1}%\nenergy       : {} (dram {}, llc {}, macc {}, cpu {})",
            self.network,
            self.config,
            fmt_ns(self.total_ns),
            fmt_ns(b.accel_ns),
            100.0 * b.accel_ns / self.total_ns.max(1e-12),
            fmt_ns(b.transfer_ns),
            100.0 * b.transfer_ns / self.total_ns.max(1e-12),
            fmt_ns(b.prep_ns),
            100.0 * b.prep_ns / self.total_ns.max(1e-12),
            fmt_ns(b.finalize_ns),
            100.0 * b.finalize_ns / self.total_ns.max(1e-12),
            fmt_ns(b.other_ns),
            100.0 * b.other_ns / self.total_ns.max(1e-12),
            fmt_bytes(self.dram_bytes),
            fmt_bytes(self.llc_bytes),
            self.dram_utilization * 100.0,
            fmt_pj(self.energy.total_pj()),
            fmt_pj(self.energy.dram_pj),
            fmt_pj(self.energy.llc_pj),
            fmt_pj(self.energy.macc_pj),
            fmt_pj(self.energy.cpu_pj),
        )
    }

    /// Machine-readable JSON of the whole report (for plotting scripts).
    pub fn to_json(&self) -> String {
        let mut w = crate::util::JsonWriter::new();
        w.begin_object();
        w.key("network").string(&self.network);
        w.key("config").string(&self.config);
        w.key("total_ns").number(self.total_ns);
        w.key("breakdown").begin_object();
        w.key("accel_ns").number(self.breakdown.accel_ns);
        w.key("transfer_ns").number(self.breakdown.transfer_ns);
        w.key("prep_ns").number(self.breakdown.prep_ns);
        w.key("finalize_ns").number(self.breakdown.finalize_ns);
        w.key("other_ns").number(self.breakdown.other_ns);
        w.end_object();
        w.key("dram_bytes").uint(self.dram_bytes);
        w.key("llc_bytes").uint(self.llc_bytes);
        w.key("dram_utilization").number(self.dram_utilization);
        w.key("sw_phase_dram_utilization")
            .number(self.sw_phase_dram_utilization);
        w.key("energy_pj").begin_object();
        w.key("total").number(self.energy.total_pj());
        w.key("soc").number(self.energy.soc_pj());
        w.key("dram").number(self.energy.dram_pj);
        w.key("llc").number(self.energy.llc_pj);
        w.key("macc").number(self.energy.macc_pj);
        w.key("spad").number(self.energy.spad_pj);
        w.key("cpu").number(self.energy.cpu_pj);
        w.end_object();
        w.key("ops").begin_array();
        for op in &self.ops {
            w.begin_object();
            w.key("name").string(&op.name);
            w.key("tag").string(&op.tag);
            w.key("strategy").string(&op.strategy);
            w.key("start_ns").number(op.start_ns);
            w.key("end_ns").number(op.end_ns);
            w.key("accel_ns").number(op.accel_ns);
            w.key("transfer_ns").number(op.transfer_ns);
            w.key("prep_ns").number(op.prep_ns);
            w.key("finalize_ns").number(op.finalize_ns);
            w.key("other_ns").number(op.other_ns);
            w.key("tiles").uint(op.tiles as u64);
            w.key("reduce_groups").uint(op.reduce_groups as u64);
            w.key("macs").uint(op.macs);
            w.key("dram_bytes").uint(op.dram_bytes);
            w.end_object();
        }
        w.end_array();
        w.end_object();
        w.finish()
    }

    /// Per-op CSV (header + one row per op) for spreadsheet/plot import.
    pub fn per_op_csv(&self) -> String {
        per_op_csv(&self.ops)
    }

    /// Per-op table (name, tag, strategy, span, components).
    pub fn per_op_table(&self) -> String {
        per_op_table(&self.ops)
    }
}

/// Per-op CSV (header + one row per op) over any record slice — shared by
/// [`SimReport`] and the unified `api::Report`.
pub fn per_op_csv(ops: &[OpRecord]) -> String {
    let mut s = String::from(
        "name,tag,strategy,start_ns,end_ns,accel_ns,transfer_ns,prep_ns,finalize_ns,other_ns,tiles,reduce_groups,macs,dram_bytes\n",
    );
    for op in ops {
        s.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
            op.name,
            op.tag,
            op.strategy,
            op.start_ns,
            op.end_ns,
            op.accel_ns,
            op.transfer_ns,
            op.prep_ns,
            op.finalize_ns,
            op.other_ns,
            op.tiles,
            op.reduce_groups,
            op.macs,
            op.dram_bytes
        ));
    }
    s
}

/// Per-op table (name, tag, strategy, span, components) over any record
/// slice — shared by [`SimReport`] and the unified `api::Report`.
pub fn per_op_table(ops: &[OpRecord]) -> String {
    let mut s = format!(
        "{:<16} {:>3} {:>7} {:>12} {:>12} {:>12} {:>12} {:>6}\n",
        "op", "tag", "strat", "span", "accel", "xfer", "cpu", "tiles"
    );
    for op in ops {
        s.push_str(&format!(
            "{:<16} {:>3} {:>7} {:>12} {:>12} {:>12} {:>12} {:>6}\n",
            op.name,
            op.tag,
            op.strategy,
            fmt_ns(op.span_ns()),
            fmt_ns(op.accel_ns),
            fmt_ns(op.transfer_ns),
            fmt_ns(op.prep_ns + op.finalize_ns + op.other_ns),
            op.tiles
        ));
    }
    s
}

/// One inference request served by the event-driven scheduler.
#[derive(Debug, Clone, Default)]
pub struct RequestRecord {
    /// Request index within the workload (arrival order).
    pub id: usize,
    /// Network this request ran.
    pub network: String,
    /// Tenant this request belongs to (`default` for single-tenant
    /// workloads).
    pub tenant: String,
    /// Arrival time at the admission queue, ns.
    pub arrival_ns: f64,
    /// Dispatch time — when the batcher released it to the SoC, ns
    /// (equals `arrival_ns` without dynamic batching).
    pub dispatch_ns: f64,
    /// Completion time (all operators fully finalized), ns.
    pub end_ns: f64,
}

impl RequestRecord {
    /// End-to-end latency of the request (queueing + service).
    pub fn latency_ns(&self) -> f64 {
        self.end_ns - self.arrival_ns
    }

    /// Time spent waiting in the admission queue, ns.
    pub fn queue_ns(&self) -> f64 {
        self.dispatch_ns - self.arrival_ns
    }
}

/// Nearest-rank percentile of an ascending-sorted slice (`q` in [0, 100]).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    let rank = ((q / 100.0) * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Per-tenant serving summary: request count, SLO attainment, queueing,
/// and tail latency for one tenant of a shared pool.
#[derive(Debug, Clone, Default)]
pub struct TenantStat {
    /// Tenant name.
    pub name: String,
    /// Dispatch priority.
    pub priority: u32,
    /// Requests this tenant issued.
    pub requests: usize,
    /// Requests that met the SLO (= `requests` when no SLO is set).
    pub slo_met: usize,
    /// Mean latency, ns.
    pub mean_ns: f64,
    /// Median latency, ns.
    pub p50_ns: f64,
    /// 99th-percentile latency, ns.
    pub p99_ns: f64,
    /// 99.9th-percentile latency, ns.
    pub p999_ns: f64,
    /// Worst latency, ns.
    pub max_ns: f64,
    /// Mean admission-queue wait, ns.
    pub mean_queue_ns: f64,
}

/// The open-loop serving section: arrival process, SLO attainment and
/// goodput, dynamic-batching outcome, admission-queue timeline, and the
/// per-tenant breakdown.
#[derive(Debug, Clone, Default)]
pub struct ServingStats {
    /// Arrival-process tag (`closed`, `poisson`, `bursty`, `trace`).
    pub arrival: String,
    /// Mean offered load, requests/second (open-loop processes only).
    pub offered_qps: Option<f64>,
    /// Latency SLO, ns (`None` = no SLO).
    pub slo_ns: Option<f64>,
    /// Requests that finished within the SLO.
    pub slo_met: usize,
    /// Fraction of requests that met the SLO (1.0 without an SLO).
    pub slo_attainment: f64,
    /// SLO-meeting requests per second of makespan (= throughput without
    /// an SLO).
    pub goodput_rps: f64,
    /// Batches dispatched (= request count without batching).
    pub batches: usize,
    /// Peak admission-queue depth.
    pub max_queue_depth: usize,
    /// Mean admission-queue wait per request, ns.
    pub mean_queue_ns: f64,
    /// Admission-queue depth timeline: (time ns, depth after the event),
    /// downsampled to at most [`Self::QUEUE_TIMELINE_CAP`] points.
    pub queue_depth: Vec<(f64, u32)>,
    /// Per-tenant breakdown, in tenant-table order.
    pub tenants: Vec<TenantStat>,
}

impl ServingStats {
    /// Maximum points kept in [`ServingStats::queue_depth`].
    pub const QUEUE_TIMELINE_CAP: usize = 512;

    /// Coarsen a queue-depth timeline to at most `cap` points. The first
    /// and last points are kept exactly; interior points are grouped into
    /// equal-count buckets and each bucket keeps its **max-depth** sample
    /// (earliest on ties), so congestion peaks survive coarsening — a
    /// stride subsampler would alias them away. Million-request open-loop
    /// runs thus emit a bounded `queue_depth` array instead of multi-MB
    /// JSON.
    fn coarsen_queue_timeline(timeline: Vec<(f64, u32)>, cap: usize) -> Vec<(f64, u32)> {
        if timeline.len() <= cap || cap < 3 {
            return timeline;
        }
        let n = timeline.len();
        let interior = &timeline[1..n - 1];
        let buckets = cap - 2;
        let mut out = Vec::with_capacity(cap);
        out.push(timeline[0]);
        for b in 0..buckets {
            // Equal-count bucket boundaries over the interior samples.
            let lo = b * interior.len() / buckets;
            let hi = (b + 1) * interior.len() / buckets;
            if let Some(&peak) = interior[lo..hi].iter().max_by(|a, b| {
                a.1.cmp(&b.1).then(b.0.total_cmp(&a.0)) // max depth, earliest tie
            }) {
                out.push(peak);
            }
        }
        out.push(timeline[n - 1]);
        out
    }

    /// Build the serving section from finished request records.
    pub fn from_requests(
        arrival: &str,
        offered_qps: Option<f64>,
        slo_ns: Option<f64>,
        batches: usize,
        tenant_order: &[(String, u32)],
        requests: &[RequestRecord],
        makespan_ns: f64,
    ) -> Self {
        let met = |r: &RequestRecord| slo_ns.is_none_or(|s| r.latency_ns() <= s);
        let slo_met = requests.iter().filter(|r| met(r)).count();
        let goodput_rps = if makespan_ns > 0.0 {
            slo_met as f64 / (makespan_ns * 1e-9)
        } else {
            0.0
        };
        // Admission-queue depth: +1 at arrival, -1 at dispatch, departures
        // first at identical instants so a dispatch-on-arrival request
        // never reads as queued.
        let mut events: Vec<(f64, i32)> = Vec::with_capacity(2 * requests.len());
        for r in requests {
            events.push((r.arrival_ns, 1));
            events.push((r.dispatch_ns, -1));
        }
        events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut depth = 0i64;
        let mut max_depth = 0i64;
        let mut timeline: Vec<(f64, u32)> = Vec::new();
        for (t, d) in events {
            depth += d as i64;
            max_depth = max_depth.max(depth);
            match timeline.last_mut() {
                Some(last) if last.0 == t => last.1 = depth.max(0) as u32,
                _ => timeline.push((t, depth.max(0) as u32)),
            }
        }
        let timeline = Self::coarsen_queue_timeline(timeline, Self::QUEUE_TIMELINE_CAP);
        let mean_queue_ns = if requests.is_empty() {
            0.0
        } else {
            requests.iter().map(RequestRecord::queue_ns).sum::<f64>() / requests.len() as f64
        };
        // Single-pass per-tenant bucketing: index tenants by name once and
        // route each request to its bucket, instead of re-scanning the
        // whole request list per tenant (O(tenants x requests) on large
        // multi-tenant runs). Requests naming an unknown tenant are
        // dropped, exactly as the per-tenant filters they replace did.
        let index: std::collections::HashMap<&str, usize> = tenant_order
            .iter()
            .enumerate()
            .map(|(i, (name, _))| (name.as_str(), i))
            .collect();
        let mut lat_buckets: Vec<Vec<f64>> = vec![Vec::new(); tenant_order.len()];
        let mut queue_sums = vec![0.0f64; tenant_order.len()];
        let mut met_counts = vec![0usize; tenant_order.len()];
        for r in requests {
            if let Some(&i) = index.get(r.tenant.as_str()) {
                lat_buckets[i].push(r.latency_ns());
                queue_sums[i] += r.queue_ns();
                if met(r) {
                    met_counts[i] += 1;
                }
            }
        }
        let tenants = tenant_order
            .iter()
            .zip(lat_buckets.iter_mut())
            .enumerate()
            .map(|(i, ((name, priority), lat))| {
                lat.sort_by(f64::total_cmp);
                let n = lat.len();
                TenantStat {
                    name: name.clone(),
                    priority: *priority,
                    requests: n,
                    slo_met: met_counts[i],
                    mean_ns: if n > 0 { lat.iter().sum::<f64>() / n as f64 } else { 0.0 },
                    p50_ns: percentile(lat, 50.0),
                    p99_ns: percentile(lat, 99.0),
                    p999_ns: percentile(lat, 99.9),
                    max_ns: lat.last().copied().unwrap_or(0.0),
                    mean_queue_ns: if n > 0 { queue_sums[i] / n as f64 } else { 0.0 },
                }
            })
            .collect();
        Self {
            arrival: arrival.to_string(),
            offered_qps,
            slo_ns,
            slo_met,
            slo_attainment: if requests.is_empty() {
                1.0
            } else {
                slo_met as f64 / requests.len() as f64
            },
            goodput_rps,
            batches,
            max_queue_depth: max_depth.max(0) as usize,
            mean_queue_ns,
            queue_depth: timeline,
            tenants,
        }
    }
}

/// Serving-mode report: per-request latencies with percentile summaries
/// plus aggregate throughput, traffic, and energy.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    /// Network name (first job's network for mixed workloads).
    pub network: String,
    /// Configuration description.
    pub config: String,
    /// Per-request records in submission order.
    pub requests: Vec<RequestRecord>,
    /// Time from t = 0 until the last request completed, ns.
    pub makespan_ns: f64,
    /// Aggregate work breakdown summed over every request's operators.
    pub breakdown: Breakdown,
    /// Mean DRAM bandwidth utilization over the makespan.
    pub dram_utilization: f64,
    /// Mean DRAM bandwidth utilization during prep/finalize phases only.
    pub sw_phase_dram_utilization: f64,
    /// Total DRAM traffic, bytes.
    pub dram_bytes: u64,
    /// Total LLC traffic, bytes.
    pub llc_bytes: u64,
    /// Energy account for the whole workload.
    pub energy: EnergyAccount,
    /// Overlap fraction + per-resource occupancy over the makespan.
    pub pipeline: PipelineStats,
    /// Routed memory-system snapshot over the makespan.
    pub memsys: MemsysSnapshot,
    /// Open-loop serving section: arrival process, SLO/goodput, batching,
    /// queue timeline, per-tenant breakdown.
    pub serving: ServingStats,
    /// Host wall-clock spent simulating, ns.
    pub sim_wallclock_ns: f64,
}

impl ServeReport {
    /// Request latencies, ascending. NaN-safe: a corrupt latency sorts to
    /// the end (`f64::total_cmp`) instead of panicking the report.
    pub fn latencies_sorted(&self) -> Vec<f64> {
        let mut v: Vec<f64> = self.requests.iter().map(RequestRecord::latency_ns).collect();
        v.sort_by(f64::total_cmp);
        v
    }

    /// Nearest-rank latency percentile (`q` in [0, 100]).
    pub fn latency_percentile(&self, q: f64) -> f64 {
        percentile(&self.latencies_sorted(), q)
    }

    /// Mean request latency, ns.
    pub fn mean_latency_ns(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests
            .iter()
            .map(RequestRecord::latency_ns)
            .sum::<f64>()
            / self.requests.len() as f64
    }

    /// Aggregate throughput in requests per second.
    pub fn throughput_rps(&self) -> f64 {
        if self.makespan_ns <= 0.0 {
            return 0.0;
        }
        self.requests.len() as f64 / (self.makespan_ns * 1e-9)
    }

    /// Multi-line human-readable summary.
    pub fn summary(&self) -> String {
        // One sort serves every percentile read below; the per-call
        // `latency_percentile` helper re-sorts the whole request list
        // each time (4 extra O(n log n) sorts per summary).
        let sorted = self.latencies_sorted();
        let mut s = format!(
            "network    : {}\nconfig     : {}\nrequests   : {}\nmakespan   : {}\nthroughput : {:.1} req/s\nlatency    : mean {}  p50 {}  p90 {}  p99 {}  p99.9 {}\n",
            self.network,
            self.config,
            self.requests.len(),
            fmt_ns(self.makespan_ns),
            self.throughput_rps(),
            fmt_ns(self.mean_latency_ns()),
            fmt_ns(percentile(&sorted, 50.0)),
            fmt_ns(percentile(&sorted, 90.0)),
            fmt_ns(percentile(&sorted, 99.0)),
            fmt_ns(percentile(&sorted, 99.9)),
        );
        let sv = &self.serving;
        s.push_str(&format!(
            "serving    : {} arrivals, goodput {:.1} req/s (SLO attainment {:.1}%), {} batch(es), peak queue {}\n",
            sv.arrival,
            sv.goodput_rps,
            100.0 * sv.slo_attainment,
            sv.batches,
            sv.max_queue_depth,
        ));
        for t in sv.tenants.iter().filter(|_| sv.tenants.len() > 1) {
            s.push_str(&format!(
                "  tenant {:<12} prio {}  {} req  p99 {}  queue {}\n",
                t.name,
                t.priority,
                t.requests,
                fmt_ns(t.p99_ns),
                fmt_ns(t.mean_queue_ns),
            ));
        }
        s.push_str(&format!(
            "dram traffic : {}\nenergy       : {}",
            fmt_bytes(self.dram_bytes),
            fmt_pj(self.energy.total_pj()),
        ));
        s
    }

    /// Machine-readable JSON of the serving report.
    pub fn to_json(&self) -> String {
        // As in `summary`: sort the latencies once for all percentiles.
        let sorted = self.latencies_sorted();
        let mut w = crate::util::JsonWriter::new();
        w.begin_object();
        w.key("network").string(&self.network);
        w.key("config").string(&self.config);
        w.key("makespan_ns").number(self.makespan_ns);
        w.key("throughput_rps").number(self.throughput_rps());
        w.key("latency_ns").begin_object();
        w.key("mean").number(self.mean_latency_ns());
        w.key("p50").number(percentile(&sorted, 50.0));
        w.key("p90").number(percentile(&sorted, 90.0));
        w.key("p99").number(percentile(&sorted, 99.0));
        w.key("p99_9").number(percentile(&sorted, 99.9));
        w.end_object();
        w.key("goodput_rps").number(self.serving.goodput_rps);
        w.key("slo_attainment").number(self.serving.slo_attainment);
        w.key("dram_bytes").uint(self.dram_bytes);
        w.key("llc_bytes").uint(self.llc_bytes);
        w.key("energy_total_pj").number(self.energy.total_pj());
        w.key("requests").begin_array();
        for r in &self.requests {
            w.begin_object();
            w.key("id").uint(r.id as u64);
            w.key("network").string(&r.network);
            w.key("tenant").string(&r.tenant);
            w.key("arrival_ns").number(r.arrival_ns);
            w.key("dispatch_ns").number(r.dispatch_ns);
            w.key("end_ns").number(r.end_ns);
            w.key("latency_ns").number(r.latency_ns());
            w.end_object();
        }
        w.end_array();
        w.end_object();
        w.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_fractions_sum_to_one() {
        let b = Breakdown {
            accel_ns: 25.0,
            transfer_ns: 34.0,
            prep_ns: 20.0,
            finalize_ns: 15.0,
            other_ns: 6.0,
        };
        let (a, t, c) = b.fractions();
        assert!((a + t + c - 1.0).abs() < 1e-12);
        assert_eq!(b.total_ns(), 100.0);
        assert_eq!(b.cpu_ns(), 41.0);
    }

    #[test]
    fn report_renders() {
        let mut r = SimReport {
            network: "cnn10".into(),
            config: "1x nvdla, dma, 1 thread".into(),
            total_ns: 1e6,
            ..Default::default()
        };
        r.breakdown.accel_ns = 2.5e5;
        r.breakdown.transfer_ns = 3.4e5;
        r.breakdown.prep_ns = 4.1e5;
        let row = r.breakdown_row();
        assert!(row.contains("cnn10"));
        assert!(r.breakdown_table().contains("accel compute"));
    }

    #[test]
    fn json_export_contains_components() {
        let mut r = SimReport {
            network: "x".into(),
            total_ns: 100.0,
            ..Default::default()
        };
        r.ops.push(OpRecord {
            name: "conv0".into(),
            tag: "C".into(),
            ..Default::default()
        });
        let j = r.to_json();
        assert!(j.contains("\"network\":\"x\""));
        assert!(j.contains("\"conv0\""));
        assert!(j.contains("\"energy_pj\""));
    }

    #[test]
    fn csv_export_has_header_and_rows() {
        let mut r = SimReport::default();
        r.ops.push(OpRecord {
            name: "fc".into(),
            tag: "F".into(),
            strategy: "DimC".into(),
            tiles: 3,
            ..Default::default()
        });
        let csv = r.per_op_csv();
        assert!(csv.starts_with("name,tag,"));
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.contains("fc,F,DimC"));
    }

    #[test]
    fn op_record_span() {
        let r = OpRecord {
            start_ns: 10.0,
            end_ns: 25.0,
            ..Default::default()
        };
        assert_eq!(r.span_ns(), 15.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 50.0), 50.0);
        assert_eq!(percentile(&v, 99.0), 99.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    fn serve_report() -> ServeReport {
        let mut r = ServeReport {
            network: "cnn10".into(),
            config: "2x nvdla / dma / 1 sw thread(s) / pipelined".into(),
            makespan_ns: 4e6,
            ..Default::default()
        };
        for i in 0..4 {
            r.requests.push(RequestRecord {
                id: i,
                network: "cnn10".into(),
                tenant: "default".into(),
                arrival_ns: i as f64 * 1e5,
                dispatch_ns: i as f64 * 1e5,
                end_ns: 1e6 + i as f64 * 1e6,
            });
        }
        r.serving = ServingStats::from_requests(
            "closed",
            None,
            None,
            r.requests.len(),
            &[("default".into(), 0)],
            &r.requests,
            r.makespan_ns,
        );
        r
    }

    #[test]
    fn serve_report_metrics() {
        let r = serve_report();
        // 4 requests over 4 ms.
        assert!((r.throughput_rps() - 1000.0).abs() < 1e-9);
        let lat = r.latencies_sorted();
        assert_eq!(lat.len(), 4);
        assert!(lat.windows(2).all(|w| w[0] <= w[1]));
        assert!(r.latency_percentile(50.0) <= r.latency_percentile(99.0));
        assert!(r.mean_latency_ns() > 0.0);
    }

    #[test]
    fn serve_report_renders_and_exports() {
        let r = serve_report();
        let s = r.summary();
        assert!(s.contains("throughput"));
        assert!(s.contains("p99"));
        assert!(s.contains("goodput"));
        let j = r.to_json();
        assert!(j.contains("\"throughput_rps\""));
        assert!(j.contains("\"p99\""));
        assert!(j.contains("\"p99_9\""));
        assert!(j.contains("\"goodput_rps\""));
        assert!(j.contains("\"tenant\""));
        assert!(j.contains("\"requests\""));
    }

    #[test]
    fn nan_latency_does_not_panic_percentiles() {
        // A corrupt (NaN) latency must degrade gracefully, never panic —
        // tail percentiles are the headline serving metric.
        let mut r = serve_report();
        r.requests[2].end_ns = f64::NAN;
        let sorted = r.latencies_sorted();
        assert_eq!(sorted.len(), 4);
        assert!(sorted[..3].windows(2).all(|w| w[0] <= w[1]));
        assert!(sorted[3].is_nan(), "NaN sorts last under total_cmp");
        let _ = r.latency_percentile(50.0);
        let _ = r.summary();
    }

    #[test]
    fn serving_stats_track_slo_queue_and_tenants() {
        let reqs: Vec<RequestRecord> = (0..8)
            .map(|i| RequestRecord {
                id: i,
                network: "cnn10".into(),
                tenant: if i % 2 == 0 { "a".into() } else { "b".into() },
                arrival_ns: i as f64 * 100.0,
                // Everything queues until t = 1000 (batched dispatch).
                dispatch_ns: 1_000.0,
                end_ns: 2_000.0 + i as f64 * 500.0,
            })
            .collect();
        let s = ServingStats::from_requests(
            "poisson",
            Some(1e7),
            Some(3_500.0),
            2,
            &[("a".into(), 1), ("b".into(), 0)],
            &reqs,
            6_000.0,
        );
        assert_eq!(s.arrival, "poisson");
        assert_eq!(s.batches, 2);
        // Latencies: 2000-100i .. grows; met when end-arrival <= 3500.
        let met = reqs.iter().filter(|r| r.latency_ns() <= 3_500.0).count();
        assert_eq!(s.slo_met, met);
        assert!((s.slo_attainment - met as f64 / 8.0).abs() < 1e-12);
        assert!((s.goodput_rps - met as f64 / 6e-6).abs() < 1.0);
        // All 8 arrive before any dispatch: the queue peaks at 8.
        assert_eq!(s.max_queue_depth, 8);
        assert!(!s.queue_depth.is_empty());
        assert_eq!(s.tenants.len(), 2);
        assert_eq!(s.tenants[0].requests + s.tenants[1].requests, 8);
        assert_eq!(s.tenants[0].priority, 1);
        assert!(s.tenants[0].mean_queue_ns > 0.0);
        assert!(s.mean_queue_ns > 0.0);
    }

    #[test]
    fn queue_timeline_is_bounded() {
        let reqs: Vec<RequestRecord> = (0..4_000)
            .map(|i| RequestRecord {
                id: i,
                network: "x".into(),
                tenant: "default".into(),
                arrival_ns: i as f64 * 10.0,
                dispatch_ns: i as f64 * 10.0 + 5.0,
                end_ns: i as f64 * 10.0 + 100.0,
            })
            .collect();
        let s = ServingStats::from_requests(
            "poisson",
            Some(1e8),
            None,
            4_000,
            &[("default".into(), 0)],
            &reqs,
            5e4,
        );
        assert!(s.queue_depth.len() <= ServingStats::QUEUE_TIMELINE_CAP);
        assert_eq!(s.slo_attainment, 1.0, "no SLO means full attainment");
    }

    #[test]
    fn long_poisson_timeline_coarsens_without_losing_the_peak() {
        // A long open-loop run: ~100k seeded-Poisson arrivals served at a
        // fixed rate, with a mid-run burst that drives the depth peak. The
        // coarsened timeline must stay bounded, keep the exact first/last
        // event instants, and preserve the max depth in some bucket — a
        // stride subsampler loses all three.
        let mut rng = crate::util::Rng::new(0xC0A25E);
        let mut t = 0.0f64;
        let mut reqs: Vec<RequestRecord> = Vec::with_capacity(100_000);
        for i in 0..100_000usize {
            // Exponential gaps (mean 100 ns), with a 5k-request burst of
            // near-zero gaps in the middle.
            let gap = if (47_000..52_000).contains(&i) {
                0.01
            } else {
                -100.0 * (1.0 - rng.range_f32(0.0, 1.0) as f64).max(1e-9).ln()
            };
            t += gap;
            // Service drains at one request per 80 ns from a single queue.
            let dispatch = t.max(i as f64 * 80.0);
            reqs.push(RequestRecord {
                id: i,
                network: "x".into(),
                tenant: "default".into(),
                arrival_ns: t,
                dispatch_ns: dispatch,
                end_ns: dispatch + 50.0,
            });
        }
        let s = ServingStats::from_requests(
            "poisson",
            Some(1e7),
            None,
            reqs.len(),
            &[("default".into(), 0)],
            &reqs,
            reqs.last().unwrap().end_ns,
        );
        assert!(
            s.queue_depth.len() <= ServingStats::QUEUE_TIMELINE_CAP,
            "timeline not bounded: {} points",
            s.queue_depth.len()
        );
        assert!(s.queue_depth.len() > 400, "suspiciously few samples kept");
        // First and last event instants survive exactly.
        assert_eq!(s.queue_depth.first().unwrap().0, reqs[0].arrival_ns);
        let last_event = reqs
            .iter()
            .flat_map(|r| [r.arrival_ns, r.dispatch_ns])
            .fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(s.queue_depth.last().unwrap().0, last_event);
        // The burst's depth peak is preserved by some bucket.
        let kept_max = s.queue_depth.iter().map(|&(_, d)| d).max().unwrap();
        assert_eq!(kept_max as usize, s.max_queue_depth);
        assert!(s.max_queue_depth > 1_000, "burst should pile up the queue");
        // Timestamps stay sorted after coarsening.
        assert!(s.queue_depth.windows(2).all(|w| w[0].0 <= w[1].0));
    }
}
