//! Parallel-sweep bench: wall-clock of the VGG16 accelerator-count sweep
//! (values 1,2,4,8) through the serial path vs the sharded engine, with
//! the layer-timing cache ablated. Emits `BENCH_sweep.json` at the
//! repository root so the sweep-throughput trajectory is tracked.
//!
//! The acceptance bar this guards: >= 2x wall-clock speedup at 4 workers
//! (cache on) over the serial uncached path, with byte-identical rows.

use smaug::api::{Report, Scenario, Session, Soc, SweepAxis};
use smaug::cache::TimingCache;
use smaug::config::{SimOptions, SocConfig};
use smaug::sched::Scheduler;
use smaug::util::{fmt_bytes, fmt_ns, JsonWriter};
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

const NET: &str = "vgg16";
const VALUES: &[usize] = &[1, 2, 4, 8];

fn run_sweep(workers: usize, cache: bool) -> anyhow::Result<(Report, f64)> {
    let t0 = Instant::now();
    let report = Session::on(Soc::default())
        .network(NET)
        .scenario(Scenario::Sweep {
            axis: SweepAxis::Accels,
            values: VALUES.to_vec(),
        })
        .workers(workers)
        .cache(cache)
        .run()?;
    Ok((report, t0.elapsed().as_secs_f64() * 1e3))
}

fn rows_fingerprint(r: &Report) -> String {
    r.sweep
        .iter()
        .map(|row| format!("{row:?}"))
        .collect::<Vec<_>>()
        .join("\n")
}

fn main() -> anyhow::Result<()> {
    println!(
        "sweep_parallel — {NET} accels sweep {VALUES:?}: serial vs sharded workers, cache ablation"
    );
    println!(
        "{:<22} {:>8} {:>6} {:>12} {:>9}",
        "config", "workers", "cache", "wall_ms", "speedup"
    );
    let configs: &[(&str, usize, bool)] = &[
        ("serial", 1, false),
        ("serial+cache", 1, true),
        ("workers4", 4, false),
        ("workers4+cache", 4, true),
    ];
    let mut w = JsonWriter::new();
    w.begin_object();
    w.key("bench").string("sweep_parallel");
    w.key("network").string(NET);
    w.key("axis").string("accels");
    w.key("values").begin_array();
    for &v in VALUES {
        w.uint(v as u64);
    }
    w.end_array();
    w.key("rows").begin_array();
    let mut serial_ms = 0.0f64;
    let mut parallel_cached_ms = f64::INFINITY;
    let mut fingerprint = String::new();
    for &(name, workers, cache) in configs {
        let (report, wall_ms) = run_sweep(workers, cache)?;
        // Every configuration must produce byte-identical sweep rows —
        // the determinism contract the test suite pins, re-checked here
        // on the bench workload.
        let fp = rows_fingerprint(&report);
        if fingerprint.is_empty() {
            fingerprint = fp;
        } else {
            assert_eq!(
                fp, fingerprint,
                "{name}: sweep rows drifted from the serial reference"
            );
        }
        if name == "serial" {
            serial_ms = wall_ms;
        }
        if name == "workers4+cache" {
            parallel_cached_ms = wall_ms;
        }
        let speedup = if wall_ms > 0.0 { serial_ms / wall_ms } else { 0.0 };
        let eng = report.sweep_engine.expect("sweep reports engine section");
        println!(
            "{:<22} {:>8} {:>6} {:>12.1} {:>8.2}x",
            name,
            workers,
            if cache { "on" } else { "off" },
            wall_ms,
            speedup
        );
        w.begin_object();
        w.key("config").string(name);
        w.key("workers").uint(workers as u64);
        w.key("cache").boolean(cache);
        w.key("wall_ms").number(wall_ms);
        w.key("speedup_vs_serial").number(speedup);
        w.key("plan_hits").uint(eng.plan_hits);
        w.key("plan_misses").uint(eng.plan_misses);
        w.key("cost_hits").uint(eng.cost_hits);
        w.key("cost_misses").uint(eng.cost_misses);
        w.end_object();
    }
    w.end_array();
    let headline = serial_ms / parallel_cached_ms;
    w.key("speedup_4workers_cache").number(headline);
    w.end_object();
    // The memoized per-layer triples double as the DSE "where does the
    // time go" view: cost one pass through a cache-attached scheduler
    // and print the heaviest layers.
    let soc = SocConfig::default();
    let cache = Arc::new(TimingCache::for_soc(&soc));
    let graph = smaug::nets::build_network(NET)?;
    Scheduler::new(soc.clone(), SimOptions::default())
        .with_cache(cache.clone())
        .run(&graph);
    println!("heaviest cached layers (contention-free, per {NET} pass):");
    for (sig, kind, _sampling, t) in cache.layer_timings().into_iter().take(3) {
        println!(
            "  {sig:<28} {kind} compute {}  traffic {}",
            fmt_ns(t.compute_ns),
            fmt_bytes(t.traffic_bytes)
        );
    }
    let out = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package dir has a parent")
        .join("BENCH_sweep.json");
    std::fs::write(&out, w.finish())?;
    println!(
        "headline: {headline:.2}x at 4 workers + cache (target >= 2x)\nwrote {}",
        out.display()
    );
    if headline < 2.0 {
        eprintln!(
            "WARNING: below the 2x acceptance bar — check host core count \
             (needs >= 4 idle cores)"
        );
    }
    Ok(())
}
