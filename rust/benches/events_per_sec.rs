//! Event-throughput bench: how many scheduler events per host second
//! the event-driven executor retires, at both granularities plus a
//! serving workload. Emits `BENCH_events.json` at the repository root
//! for the CI bench gate (`scripts/compare_bench.py` vs
//! `bench_baselines/events.json`).
//!
//! An "event" is one unit the executor's ready queue dispatches:
//!
//! * op granularity — accelerator ops cost two events (CPU dispatch +
//!   hardware completion), CPU-only and source ops one;
//! * tile granularity — every task in the lowered task graph is one
//!   event (source / prep chunk / tile / finalize).
//!
//! The measured loop is `Scheduler::run` / `serve_workload` directly
//! (no Session front door), matching `perf_hotpath`'s methodology so
//! graph construction and report assembly stay out of the numbers.

use smaug::config::{SimOptions, SocConfig};
use smaug::graph::Graph;
use smaug::ir::OpWork;
use smaug::nets;
use smaug::sched::Scheduler;
use smaug::util::JsonWriter;
use std::path::Path;
use std::time::Instant;

/// Events the op-granularity executor dispatches for `jobs`.
fn op_events(jobs: &[(f64, &Graph)]) -> u64 {
    let sched = Scheduler::new(SocConfig::default(), SimOptions::default());
    let tg = sched.lower_workload(jobs);
    tg.ops
        .iter()
        .map(|n| match n.work {
            OpWork::Accel(_) => 2u64,
            _ => 1u64,
        })
        .sum()
}

/// Events the tile-granularity executor dispatches for `jobs`.
fn tile_events(jobs: &[(f64, &Graph)]) -> u64 {
    let sched = Scheduler::new(SocConfig::default(), SimOptions::default());
    sched.lower_workload(jobs).tasks.len() as u64
}

/// Time `f` over `iters` runs (after one warmup) and return events/sec.
fn throughput<F: FnMut()>(events: u64, iters: u32, mut f: F) -> f64 {
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    (events as f64 * iters as f64) / t0.elapsed().as_secs_f64()
}

fn main() -> anyhow::Result<()> {
    println!("events_per_sec — event-executor throughput (events/host-second)");
    let soc = SocConfig::default();
    let vgg = nets::build_network("vgg16")?;
    let lenet = nets::build_network("lenet5")?;

    // Op granularity: VGG16 through the op-pipelined executor.
    let op_opts = SimOptions {
        pipeline: true,
        ..SimOptions::default()
    };
    let n_op = op_events(&[(0.0, &vgg)]);
    let eps_op = throughput(n_op, 10, || {
        let mut sched = Scheduler::new(soc.clone(), op_opts.clone());
        std::hint::black_box(sched.run(&vgg));
    });

    // Tile granularity: the same network, per-tile frontier.
    let tile_opts = SimOptions {
        tile_pipeline: true,
        ..SimOptions::default()
    };
    let n_tile = tile_events(&[(0.0, &vgg)]);
    let eps_tile = throughput(n_tile, 5, || {
        let mut sched = Scheduler::new(soc.clone(), tile_opts.clone());
        std::hint::black_box(sched.run(&vgg));
    });

    // Serving: 64 staggered lenet5 requests through the op-level
    // executor — the multi-job frontier the ready queues were built for.
    let serve_jobs: Vec<(f64, &Graph)> =
        (0..64).map(|i| (i as f64 * 20_000.0, &lenet)).collect();
    let n_serve = op_events(&serve_jobs);
    let eps_serve = throughput(n_serve, 5, || {
        let mut sched = Scheduler::new(soc.clone(), op_opts.clone());
        std::hint::black_box(sched.serve_workload(&serve_jobs));
    });

    println!("{:<28} {:>10} {:>16}", "workload", "events", "events/sec");
    for (name, n, eps) in [
        ("vgg16 op-granularity", n_op, eps_op),
        ("vgg16 tile-granularity", n_tile, eps_tile),
        ("lenet5 serve x64 (op)", n_serve, eps_serve),
    ] {
        println!("{name:<28} {n:>10} {eps:>16.0}");
    }

    let mut w = JsonWriter::new();
    w.begin_object();
    w.key("bench").string("events_per_sec");
    w.key("events_op_vgg16").uint(n_op);
    w.key("events_tile_vgg16").uint(n_tile);
    w.key("events_serve64").uint(n_serve);
    w.key("events_per_sec_op_vgg16").number(eps_op);
    w.key("events_per_sec_tile_vgg16").number(eps_tile);
    w.key("events_per_sec_serve64").number(eps_serve);
    w.end_object();
    let out = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package dir has a parent")
        .join("BENCH_events.json");
    std::fs::write(&out, w.finish())?;
    println!("wrote {}", out.display());
    Ok(())
}
