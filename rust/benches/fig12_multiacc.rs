//! Bench harness for paper Fig 12: execution time of multi-accelerator
//! systems (1, 2, 4, 8 accelerators) across the network zoo.

use smaug::figures;
use smaug::nets::ALL_NETWORKS;

fn main() -> anyhow::Result<()> {
    let rows = figures::fig12(ALL_NETWORKS, &[1, 2, 4, 8])?;
    figures::print_fig12(&rows);
    Ok(())
}
