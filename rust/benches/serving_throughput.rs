//! Serving-throughput bench: requests/sec and latency percentiles vs the
//! accelerator pool size (1, 2, 4, 8), on the event-driven scheduler with
//! pipelining on, driven through the scenario API. Emits
//! `BENCH_serving.json` at the repository root so the serving-performance
//! trajectory is tracked from this change on.

use smaug::api::{Scenario, Session, Soc};
use smaug::config::AccelKind;
use smaug::util::{fmt_ns, JsonWriter};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let net = "cnn10";
    let requests = 16usize;
    println!("serving_throughput — {requests} concurrent requests of {net} (pipelined, DMA, 8 sw threads)");
    println!(
        "{:<7} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "accels", "req/s", "p50", "p90", "p99", "makespan"
    );
    let mut w = JsonWriter::new();
    w.begin_object();
    w.key("bench").string("serving_throughput");
    w.key("network").string(net);
    w.key("requests").uint(requests as u64);
    w.key("rows").begin_array();
    for &accels in &[1usize, 2, 4, 8] {
        let r = Session::on(Soc::builder().accels(AccelKind::Nvdla, accels).build())
            .network(net)
            .threads(8)
            .scenario(Scenario::Serving {
                requests,
                arrival_interval_ns: 0.0,
            })
            .run()?;
        let l = r.latency.expect("serving reports latency stats");
        let rps = r.throughput_rps.unwrap_or(0.0);
        println!(
            "{:<7} {:>12.1} {:>12} {:>12} {:>12} {:>12}",
            accels,
            rps,
            fmt_ns(l.p50_ns),
            fmt_ns(l.p90_ns),
            fmt_ns(l.p99_ns),
            fmt_ns(r.total_ns)
        );
        w.begin_object();
        w.key("accels").uint(accels as u64);
        w.key("throughput_rps").number(rps);
        w.key("p50_ns").number(l.p50_ns);
        w.key("p90_ns").number(l.p90_ns);
        w.key("p99_ns").number(l.p99_ns);
        w.key("mean_ns").number(l.mean_ns);
        w.key("makespan_ns").number(r.total_ns);
        w.key("dram_bytes").uint(r.dram_bytes);
        w.end_object();
    }
    w.end_array();
    w.end_object();
    let out = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package dir has a parent")
        .join("BENCH_serving.json");
    std::fs::write(&out, w.finish())?;
    println!("wrote {}", out.display());
    Ok(())
}
