//! Serving-throughput bench: requests/sec and latency percentiles vs the
//! accelerator pool size (1, 2, 4, 8), on the event-driven scheduler with
//! pipelining on, driven through the scenario API. Emits
//! `BENCH_serving.json` at the repository root so the serving-performance
//! trajectory is tracked from this change on.

use smaug::api::{Report, Scenario, Session, Soc};
use smaug::config::{AccelKind, ServeOptions};
use smaug::util::{fmt_ns, JsonWriter};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let net = "cnn10";
    let requests = 16usize;
    println!("serving_throughput — {requests} concurrent requests of {net} (pipelined, DMA, 8 sw threads)");
    println!(
        "{:<7} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "accels", "req/s", "p50", "p90", "p99", "makespan"
    );
    let pool_sizes = [1usize, 2, 4, 8];
    let mut reports: Vec<(usize, Report)> = Vec::with_capacity(pool_sizes.len());
    for &accels in &pool_sizes {
        let r = Session::on(Soc::builder().accels(AccelKind::Nvdla, accels).build())
            .network(net)
            .threads(8)
            .scenario(Scenario::Serving(ServeOptions::closed(requests, 0.0)))
            .run()?;
        let l = r.latency.expect("serving reports latency stats");
        println!(
            "{:<7} {:>12.1} {:>12} {:>12} {:>12} {:>12}",
            accels,
            r.throughput_rps.unwrap_or(0.0),
            fmt_ns(l.p50_ns),
            fmt_ns(l.p90_ns),
            fmt_ns(l.p99_ns),
            fmt_ns(r.total_ns)
        );
        reports.push((accels, r));
    }
    let rps_at = |n: usize| {
        reports
            .iter()
            .find(|(a, _)| *a == n)
            .and_then(|(_, r)| r.throughput_rps)
            .unwrap_or(0.0)
    };
    let mut w = JsonWriter::new();
    w.begin_object();
    w.key("bench").string("serving_throughput");
    w.key("network").string(net);
    w.key("requests").uint(requests as u64);
    // Headline metric for the CI bench gate: how much throughput the
    // full 8-accelerator pool buys over a single accelerator.
    w.key("throughput_scaling_8x_vs_1x")
        .number(rps_at(8) / rps_at(1).max(1e-9));
    w.key("rows").begin_array();
    for (accels, r) in &reports {
        let l = r.latency.expect("serving reports latency stats");
        w.begin_object();
        w.key("accels").uint(*accels as u64);
        w.key("throughput_rps").number(r.throughput_rps.unwrap_or(0.0));
        w.key("p50_ns").number(l.p50_ns);
        w.key("p90_ns").number(l.p90_ns);
        w.key("p99_ns").number(l.p99_ns);
        w.key("mean_ns").number(l.mean_ns);
        w.key("makespan_ns").number(r.total_ns);
        w.key("dram_bytes").uint(r.dram_bytes);
        w.end_object();
    }
    w.end_array();
    w.end_object();
    let out = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package dir has a parent")
        .join("BENCH_serving.json");
    std::fs::write(&out, w.finish())?;
    println!("wrote {}", out.display());
    Ok(())
}
