//! Serving-throughput bench: requests/sec and latency percentiles vs the
//! accelerator pool size (1, 2, 4, 8), on the event-driven scheduler with
//! pipelining on. Emits `BENCH_serving.json` at the repository root so
//! the serving-performance trajectory is tracked from this change on.

use smaug::config::{ServeOptions, SimOptions, SocConfig};
use smaug::nets;
use smaug::sim::Simulator;
use smaug::util::{fmt_ns, JsonWriter};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let net = "cnn10";
    let requests = 16usize;
    println!("serving_throughput — {requests} concurrent requests of {net} (pipelined, DMA, 8 sw threads)");
    println!(
        "{:<7} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "accels", "req/s", "p50", "p90", "p99", "makespan"
    );
    let mut w = JsonWriter::new();
    w.begin_object();
    w.key("bench").string("serving_throughput");
    w.key("network").string(net);
    w.key("requests").uint(requests as u64);
    w.key("rows").begin_array();
    let graph = nets::build_network(net)?;
    for &accels in &[1usize, 2, 4, 8] {
        let opts = SimOptions {
            num_accels: accels,
            sw_threads: 8,
            pipeline: true,
            ..SimOptions::default()
        };
        let serve = ServeOptions {
            requests,
            arrival_interval_ns: 0.0,
        };
        let r = Simulator::new(SocConfig::default(), opts).serve(&graph, &serve)?;
        let (p50, p90, p99) = (
            r.latency_percentile(50.0),
            r.latency_percentile(90.0),
            r.latency_percentile(99.0),
        );
        println!(
            "{:<7} {:>12.1} {:>12} {:>12} {:>12} {:>12}",
            accels,
            r.throughput_rps(),
            fmt_ns(p50),
            fmt_ns(p90),
            fmt_ns(p99),
            fmt_ns(r.makespan_ns)
        );
        w.begin_object();
        w.key("accels").uint(accels as u64);
        w.key("throughput_rps").number(r.throughput_rps());
        w.key("p50_ns").number(p50);
        w.key("p90_ns").number(p90);
        w.key("p99_ns").number(p99);
        w.key("mean_ns").number(r.mean_latency_ns());
        w.key("makespan_ns").number(r.makespan_ns);
        w.key("dram_bytes").uint(r.dram_bytes);
        w.end_object();
    }
    w.end_array();
    w.end_object();
    let out = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package dir has a parent")
        .join("BENCH_serving.json");
    std::fs::write(&out, w.finish())?;
    println!("wrote {}", out.display());
    Ok(())
}
