//! Transformer-workload bench: simulated latency of the `bert-tiny`
//! encoder and one `decode` KV-cache step, plus the decode bandwidth
//! signature — how much a DRAM 1 -> 4 channel widening buys decode
//! versus vgg16. Emits `BENCH_transformer.json` at the repository root
//! for the CI bench gate (`scripts/compare_bench.py` vs
//! `bench_baselines/transformer.json`).
//!
//! All four metrics are simulated-time and bit-deterministic, so the
//! gate is immune to CI-runner noise. The bench also hard-fails inline
//! if the bandwidth leverage ever drops to <= 1.0 — decode losing its
//! memory-bound character is a modeling bug, not a perf regression.

use smaug::config::{SimOptions, SocConfig};
use smaug::nets;
use smaug::sched::Scheduler;
use smaug::util::JsonWriter;
use std::path::Path;

/// Simulated latency (ns) of `net` on a SoC with `channels` DRAM
/// channels, default options.
fn latency_ns(net: &str, channels: usize) -> anyhow::Result<f64> {
    let g = nets::build_network(net)?;
    let soc = SocConfig {
        dram_channels: channels,
        ..SocConfig::default()
    };
    let mut sched = Scheduler::new(soc, SimOptions::default());
    Ok(sched.run(&g).total_ns)
}

fn main() -> anyhow::Result<()> {
    println!("transformer_inference — simulated transformer latencies");

    let bert_us = latency_ns("bert-tiny", 1)? / 1e3;
    let decode_us = latency_ns("decode", 1)? / 1e3;

    // Bandwidth signature: decode streams its KV cache and weights once
    // per step, so extra DRAM channels move it; vgg16 re-uses operands
    // heavily and barely notices.
    let decode_speedup = latency_ns("decode", 1)? / latency_ns("decode", 4)?;
    let vgg_speedup = latency_ns("vgg16", 1)? / latency_ns("vgg16", 4)?;
    let leverage = decode_speedup / vgg_speedup;

    println!("{:<34} {:>12}", "metric", "value");
    for (name, v) in [
        ("bert-tiny latency (us)", bert_us),
        ("decode step latency (us)", decode_us),
        ("decode speedup 1->4 channels", decode_speedup),
        ("leverage vs vgg16", leverage),
    ] {
        println!("{name:<34} {v:>12.3}");
    }

    // Hard floors (modeling invariants, not perf): more bandwidth must
    // help decode at all, and must help it strictly more than vgg16.
    assert!(
        decode_speedup > 1.0,
        "decode must improve with DRAM channels ({decode_speedup:.3}x)"
    );
    assert!(
        leverage > 1.0,
        "decode bandwidth leverage {leverage:.3}x must exceed vgg16's"
    );

    let mut w = JsonWriter::new();
    w.begin_object();
    w.key("bench").string("transformer_inference");
    w.key("bert_tiny_us").number(bert_us);
    w.key("decode_step_us").number(decode_us);
    w.key("decode_bandwidth_speedup_4ch").number(decode_speedup);
    w.key("bandwidth_leverage_vs_vgg16").number(leverage);
    w.end_object();
    let out = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package dir has a parent")
        .join("BENCH_transformer.json");
    std::fs::write(&out, w.finish())?;
    println!("wrote {}", out.display());
    Ok(())
}
