//! Bench harness for paper Fig 11: ACP vs DMA performance and energy
//! across the network zoo (paper: 17-55% speedup, up to 56% energy win).

use smaug::figures;
use smaug::nets::ALL_NETWORKS;

fn main() -> anyhow::Result<()> {
    let rows = figures::fig11(ALL_NETWORKS)?;
    figures::print_fig11(&rows);
    Ok(())
}
