//! Bench harness for paper Fig 18: combined effect of ACP + 8
//! accelerators + 8 software threads (paper: 42-80% latency reduction,
//! 1.8-5x speedup).

use smaug::figures;
use smaug::nets::ALL_NETWORKS;

fn main() -> anyhow::Result<()> {
    let rows = figures::fig18(ALL_NETWORKS)?;
    figures::print_fig18(&rows);
    Ok(())
}
