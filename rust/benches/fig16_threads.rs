//! Bench harness for paper Fig 16: multithreaded data management
//! (1, 2, 4, 8 software threads; paper: 3-4x prep/finalize speedup,
//! up to 37% end-to-end).

use smaug::figures;
use smaug::nets::ALL_NETWORKS;

fn main() -> anyhow::Result<()> {
    let rows = figures::fig16(ALL_NETWORKS, &[1, 2, 4, 8])?;
    figures::print_fig16(&rows);
    Ok(())
}
