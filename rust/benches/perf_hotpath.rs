//! Performance microbenchmarks of the simulator's own hot paths (the
//! EXPERIMENTS.md SS-Perf targets): tiling-plan construction, bandwidth-
//! timeline requests, end-to-end simulation throughput. Drives the
//! scheduler directly (not the Session front door) so graph construction
//! and report assembly stay out of the measured loop.

use smaug::config::{AccelKind, SimOptions, SocConfig};
use smaug::mem::BandwidthTimeline;
use smaug::nets;
use smaug::sched::Scheduler;
use smaug::tiling::{plan_conv, ConvParams};
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, iters: u32, mut f: F) {
    // Warmup.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("  {name:<36} {:>12.3} us/iter", per * 1e6);
}

fn main() {
    println!("perf_hotpath — simulator hot-path microbenchmarks");
    let soc = SocConfig::default();

    let conv = ConvParams {
        h: 32, w: 32, c: 512, k: 512, r: 3, s: 3, stride: 1, pad_same: true,
    };
    bench("plan_conv(vgg-style 512ch)", 50, || {
        std::hint::black_box(plan_conv(&conv, &soc));
    });

    bench("bandwidth_timeline 10k requests", 10, || {
        let mut bw = BandwidthTimeline::new(20.0);
        let mut t = 0.0;
        for i in 0..10_000u64 {
            let (_, e) = bw.request(t, 1000 + (i % 97) * 64, 20.0);
            if i % 3 == 0 {
                t = e;
            }
        }
        std::hint::black_box(bw.total_bytes());
    });

    for net in ["cnn10", "vgg16", "resnet50"] {
        let g = nets::build_network(net).unwrap();
        let iters = if net == "resnet50" { 3 } else { 20 };
        bench(&format!("simulate {net} (baseline)"), iters, || {
            let mut sched = Scheduler::new(SocConfig::default(), SimOptions::default());
            std::hint::black_box(sched.run(&g));
        });
    }
    let g = nets::build_network("vgg16").unwrap();
    bench("simulate vgg16 (8 accel, acp, 8thr)", 10, || {
        let mut sched = Scheduler::new(SocConfig::default(), SimOptions::optimized());
        std::hint::black_box(sched.run(&g));
    });
    // A heterogeneous pool exercises the per-instance model dispatch.
    let hetero = SimOptions {
        accel_pool: vec![
            AccelKind::Nvdla,
            AccelKind::Systolic,
            AccelKind::Nvdla,
            AccelKind::Systolic,
        ],
        pipeline: true,
        ..SimOptions::default()
    };
    bench("simulate vgg16 (hetero 4-pool, piped)", 10, || {
        let mut sched = Scheduler::new(SocConfig::default(), hetero.clone());
        std::hint::black_box(sched.run(&g));
    });

    // IR lowering throughput: with job templates, replicating a job is
    // a flat stamp (CSR copy + id offsets), not a re-derivation — the
    // 16-job lowering should cost far less than 16x the 1-job one.
    let sched = Scheduler::new(SocConfig::default(), SimOptions::default());
    bench("lower vgg16 x1 job (tile tasks)", 20, || {
        std::hint::black_box(sched.lower_workload(&[(0.0, &g)]));
    });
    let jobs: Vec<_> = (0..16).map(|i| (i as f64 * 1_000.0, &g)).collect();
    bench("lower vgg16 x16 jobs (templated)", 20, || {
        std::hint::black_box(sched.lower_workload(&jobs));
    });
}
