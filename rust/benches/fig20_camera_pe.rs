//! Bench harness for paper Fig 19/20: the camera + CNN10 pipeline on
//! systolic arrays of decreasing size against the 30 FPS budget.

use smaug::figures;

fn main() -> anyhow::Result<()> {
    let (cam_ns, rows) =
        figures::fig20(&[(8, 8), (4, 8), (4, 4), (2, 4), (2, 2), (1, 2), (1, 1)])?;
    figures::print_fig20(cam_ns, &rows);
    Ok(())
}
