//! Cluster-scaling bench: VGG16 data-parallel throughput across SoC
//! counts on an unbounded vs throttled fabric, plus the pipeline split.
//! Emits `BENCH_cluster.json` at the repository root; CI gates the two
//! headline metrics against `bench_baselines/cluster.json`.
//!
//! Both headlines are simulated-time, so they are deterministic:
//!
//! * `speedup_dp4_vs_1` — 4-SoC data-parallel throughput over 1-SoC, on
//!   an unbounded fabric. The partitioner's ideal-scaling contract says
//!   this is exactly 4.0.
//! * `throttled_ratio_dp4` — 4-SoC throughput with a starved root NIC
//!   divided by the unbounded figure. Must never exceed 1.0 (a throttled
//!   fabric cannot help), and tracks how hard the modeled scatter path
//!   bites.

use smaug::api::{Report, Scenario, Session, Soc};
use smaug::cluster::Partition;
use smaug::util::JsonWriter;
use std::path::Path;

const NET: &str = "vgg16";
const QUERIES: usize = 8;
const THROTTLED_NIC_GBPS: f64 = 0.05;

fn run(socs: usize, partition: Partition, nic_gbps: f64, workers: usize) -> anyhow::Result<Report> {
    let mut s = Session::on(Soc::default())
        .network(NET)
        .cluster(socs)
        .partition(partition)
        .queries(QUERIES)
        .workers(workers)
        .scenario(Scenario::Inference);
    if nic_gbps > 0.0 {
        s = s.nic_gbps(nic_gbps);
    }
    s.run()
}

fn main() -> anyhow::Result<()> {
    println!("cluster_scaling — {NET}, {QUERIES} queries, dp/pp across SoC counts");
    println!(
        "{:<26} {:>5} {:>10} {:>14} {:>10}",
        "config", "socs", "nic", "makespan_ms", "q/s"
    );
    let mut w = JsonWriter::new();
    w.begin_object();
    w.key("bench").string("cluster_scaling");
    w.key("network").string(NET);
    w.key("queries").uint(QUERIES as u64);
    w.key("rows").begin_array();
    let mut qps_by_name: Vec<(String, f64)> = Vec::new();
    let configs: &[(&str, usize, Partition, f64)] = &[
        ("dp1", 1, Partition::DataParallel, 0.0),
        ("dp2", 2, Partition::DataParallel, 0.0),
        ("dp4", 4, Partition::DataParallel, 0.0),
        ("dp4-throttled", 4, Partition::DataParallel, THROTTLED_NIC_GBPS),
        ("pp4", 4, Partition::Pipeline { stages: 4 }, 0.0),
    ];
    for &(name, socs, partition, nic) in configs {
        let report = run(socs, partition, nic, 4)?;
        let c = report.cluster.as_ref().expect("cluster section");
        println!(
            "{:<26} {:>5} {:>10} {:>14.3} {:>10.2}",
            name,
            socs,
            if nic > 0.0 { format!("{nic} GB/s") } else { "unbound".to_string() },
            c.makespan_ns / 1e6,
            c.throughput_qps
        );
        w.begin_object();
        w.key("config").string(name);
        w.key("socs").uint(socs as u64);
        w.key("partition").string(&c.partition);
        w.key("nic_gbps").number(nic);
        w.key("makespan_ns").number(c.makespan_ns);
        w.key("throughput_qps").number(c.throughput_qps);
        w.key("fabric_bytes").uint(c.fabric_bytes);
        w.key("collective_ns").number(c.collective.time_ns);
        w.end_object();
        qps_by_name.push((name.to_string(), c.throughput_qps));
    }
    w.end_array();
    let get = |n: &str| qps_by_name.iter().find(|(k, _)| k == n).unwrap().1;
    let speedup = get("dp4") / get("dp1");
    let throttled_ratio = get("dp4-throttled") / get("dp4");
    w.key("speedup_dp4_vs_1").number(speedup);
    w.key("throttled_ratio_dp4").number(throttled_ratio);
    w.end_object();

    // Determinism spot-check on the sharded per-stage sims: the pipeline
    // split must not depend on the worker count.
    let a = run(4, Partition::Pipeline { stages: 4 }, 0.0, 1)?;
    let b = run(4, Partition::Pipeline { stages: 4 }, 0.0, 4)?;
    let (ma, mb) = (
        a.cluster.as_ref().unwrap().makespan_ns,
        b.cluster.as_ref().unwrap().makespan_ns,
    );
    assert_eq!(ma.to_bits(), mb.to_bits(), "pp makespan drifted with workers");

    let out = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package dir has a parent")
        .join("BENCH_cluster.json");
    std::fs::write(&out, w.finish())?;
    println!(
        "headline: dp4 speedup {speedup:.2}x (ideal 4.0), throttled ratio \
         {throttled_ratio:.2} (must stay <= 1.0)\nwrote {}",
        out.display()
    );
    assert!(
        speedup >= 3.0,
        "dp4 on an unbounded fabric fell below the 3x acceptance floor"
    );
    assert!(
        throttled_ratio <= 1.0 + 1e-9,
        "a throttled fabric must never beat an unbounded one"
    );
    Ok(())
}
