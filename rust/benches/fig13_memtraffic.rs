//! Bench harness for paper Fig 13: DRAM traffic growth and bandwidth
//! utilization as the accelerator count scales (paper: <=6% growth,
//! better utilization, ~60% transfer-time drop).

use smaug::figures;
use smaug::nets::ALL_NETWORKS;

fn main() -> anyhow::Result<()> {
    let rows = figures::fig12(ALL_NETWORKS, &[1, 2, 4, 8])?;
    figures::print_fig13(&rows);
    Ok(())
}
