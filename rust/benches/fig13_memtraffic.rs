//! Bench harness for paper Fig 13: DRAM traffic growth and bandwidth
//! utilization as the accelerator count scales (paper: <=6% growth,
//! better utilization, ~60% transfer-time drop) — extended with the
//! routed memory-system sweep: the same workloads across
//! `--dram-channels 1,2,4` on a 2-accelerator tile-pipelined SoC,
//! emitting `BENCH_memsys.json` (per-channel traffic/occupancy plus the
//! end-to-end win from memory parallelism) at the repository root.

use smaug::api::{Report, Session, Soc};
use smaug::config::AccelKind;
use smaug::figures;
use smaug::nets::ALL_NETWORKS;
use smaug::util::{fmt_ns, JsonWriter};
use std::path::Path;

const CHANNEL_NETS: &[&str] = &["cnn10", "vgg16"];
const CHANNELS: &[usize] = &[1, 2, 4];

fn run(net: &str, channels: usize) -> anyhow::Result<Report> {
    Session::on(
        Soc::builder()
            .accels(AccelKind::Nvdla, 2)
            .dram_channels(channels)
            .build(),
    )
    .network(net)
    .threads(8)
    .tile_pipeline(true)
    .run()
}

fn main() -> anyhow::Result<()> {
    // The classic Fig-13 table (ALL_NETWORKS x four pools, incl.
    // ImageNet-scale nets) is the slow part and PR CI only needs the
    // gated channel sweep below — the figure portion is opt-in
    // (nightly.yml sets SMAUG_FIG_FULL=1).
    if std::env::var("SMAUG_FIG_FULL").is_ok() {
        let rows = figures::fig12(ALL_NETWORKS, &[1, 2, 4, 8])?;
        figures::print_fig13(&rows);
    } else {
        println!("fig13 table skipped (set SMAUG_FIG_FULL=1 for the full figure sweep)");
    }

    // Routed memory-system sweep: channel count as the SoC-integration
    // DSE axis on a 2-accel tile-pipelined SoC.
    println!("\nmemsys — DRAM channel sweep (2x nvdla, tile-pipelined, 8 threads)");
    println!(
        "{:<8} {:>9} {:>12} {:>9} {:>14} {:>20}",
        "net", "channels", "latency", "speedup", "dram traffic", "per-channel busy"
    );
    let mut w = JsonWriter::new();
    w.begin_object();
    w.key("bench").string("memsys_channels");
    w.key("pool").string("2x nvdla");
    w.key("rows").begin_array();
    let mut headline = 0.0f64;
    for &net in CHANNEL_NETS {
        let mut one_ns = 0.0f64;
        let mut one_bytes = 0u64;
        for &ch in CHANNELS {
            let rep = run(net, ch)?;
            if ch == 1 {
                one_ns = rep.total_ns;
                one_bytes = rep.dram_bytes;
            } else {
                // Routing moves *when* bytes stream, never how many.
                assert_eq!(
                    rep.dram_bytes, one_bytes,
                    "{net}/{ch}ch: channel count must not change traffic"
                );
            }
            let speedup = one_ns / rep.total_ns.max(1e-12);
            if net == "vgg16" && ch == *CHANNELS.last().unwrap() {
                headline = speedup;
            }
            let m = rep.memsys.as_ref().expect("single runs report memsys");
            println!(
                "{:<8} {:>9} {:>12} {:>8.2}x {:>14} {:>20}",
                net,
                ch,
                fmt_ns(rep.total_ns),
                speedup,
                rep.dram_bytes,
                m.busy_string()
            );
            w.begin_object();
            w.key("net").string(net);
            w.key("channels").uint(ch as u64);
            w.key("total_ns").number(rep.total_ns);
            w.key("speedup_vs_1ch").number(speedup);
            w.key("dram_bytes").uint(rep.dram_bytes);
            m.write_per_channel(&mut w);
            w.end_object();
        }
    }
    w.end_array();
    w.key("speedup_vgg16_4ch").number(headline);
    w.end_object();
    let out = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package dir has a parent")
        .join("BENCH_memsys.json");
    std::fs::write(&out, w.finish())?;
    println!(
        "headline: {headline:.2}x vgg16 at 4 channels vs 1 (target >= 1.1x)\nwrote {}",
        out.display()
    );
    // Simulated-time speedup — deterministic — so the acceptance bar is
    // a hard failure CI can see, exactly like pipeline_overlap's.
    if headline < 1.1 {
        eprintln!("FAIL: {headline:.2}x is below the 1.1x acceptance bar");
        std::process::exit(1);
    }
    Ok(())
}
