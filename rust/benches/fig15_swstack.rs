//! Bench harness for paper Fig 15: the baseline software stack's time
//! split into data preparation / finalization / other (paper: prep +
//! finalization ~85% of software time).

use smaug::figures;
use smaug::nets::ALL_NETWORKS;

fn main() -> anyhow::Result<()> {
    let rows = figures::fig01(ALL_NETWORKS)?;
    figures::print_fig15(&rows);
    Ok(())
}
