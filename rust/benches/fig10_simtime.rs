//! Bench harness for paper Fig 10: simulator wall-clock per network
//! (the paper reports gem5-Aladdin hours; our transaction-level
//! simulator runs the same sweeps in milliseconds-to-seconds).

use smaug::config::SimOptions;
use smaug::figures;
use smaug::nets::ALL_NETWORKS;

fn main() -> anyhow::Result<()> {
    println!("Fig 10 — simulation wall-clock per network (paper: minutes-hours on gem5)");
    for net in ALL_NETWORKS {
        let t0 = std::time::Instant::now();
        let r = figures::run_net(net, SimOptions::default())?;
        println!(
            "  {:<10} simulated {:>12}   host wall-clock {:>10.2?}",
            net,
            smaug::util::fmt_ns(r.total_ns),
            t0.elapsed()
        );
    }
    Ok(())
}
