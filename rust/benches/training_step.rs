//! Training-step bench (extension — the paper plans training support):
//! simulates one SGD training step (forward + dX/dW backward GEMMs +
//! parameter updates) vs a forward-only pass, baseline and optimized.

use smaug::config::{SimOptions, SocConfig};
use smaug::graph::training_step;
use smaug::nets;
use smaug::sim::Simulator;
use smaug::util::fmt_ns;

fn main() -> anyhow::Result<()> {
    println!("Training-step extension — one SGD step vs single-batch inference");
    println!(
        "{:<10} {:>14} {:>14} {:>7} {:>16}",
        "net", "inference", "train step", "ratio", "train(optimized)"
    );
    for net in ["minerva", "lenet5", "cnn10", "vgg16", "elu16"] {
        let fwd = nets::build_network(net)?;
        let train = training_step(&fwd);
        let run = |g, o| -> anyhow::Result<f64> {
            Ok(Simulator::new(SocConfig::default(), o).run(g)?.total_ns)
        };
        let infer = run(&fwd, SimOptions::default())?;
        let step = run(&train, SimOptions::default())?;
        let opt = run(&train, SimOptions::optimized())?;
        println!(
            "{:<10} {:>14} {:>14} {:>6.2}x {:>16}",
            net,
            fmt_ns(infer),
            fmt_ns(step),
            step / infer,
            fmt_ns(opt)
        );
    }
    Ok(())
}
