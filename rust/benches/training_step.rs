//! Training-step bench (extension — the paper plans training support):
//! simulates one SGD training step (forward + dX/dW backward GEMMs +
//! parameter updates) vs a forward-only pass, baseline and optimized,
//! through the `Scenario::Training` variant.

use smaug::api::{Scenario, Session, Soc};
use smaug::config::{AccelKind, InterfaceKind};
use smaug::util::fmt_ns;

fn main() -> anyhow::Result<()> {
    println!("Training-step extension — one SGD step vs single-batch inference");
    println!(
        "{:<10} {:>14} {:>14} {:>7} {:>16}",
        "net", "inference", "train step", "ratio", "train(optimized)"
    );
    for net in ["minerva", "lenet5", "cnn10", "vgg16", "elu16"] {
        let infer = Session::on(Soc::default())
            .network(net)
            .scenario(Scenario::Inference)
            .run()?
            .total_ns;
        let step = Session::on(Soc::default())
            .network(net)
            .scenario(Scenario::Training)
            .run()?
            .total_ns;
        let opt = Session::on(Soc::builder().accels(AccelKind::Nvdla, 8).build())
            .network(net)
            .interface(InterfaceKind::Acp)
            .threads(8)
            .scenario(Scenario::Training)
            .run()?
            .total_ns;
        println!(
            "{:<10} {:>14} {:>14} {:>6.2}x {:>16}",
            net,
            fmt_ns(infer),
            fmt_ns(step),
            step / infer,
            fmt_ns(opt)
        );
    }
    Ok(())
}
