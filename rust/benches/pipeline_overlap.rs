//! Cross-op pipelining bench: VGG16 single-batch latency at the three
//! event-engine granularities — pipelining off (the serial reference),
//! operator-level pipelining, and tile-level pipelining — on a
//! 2x-NVDLA pool and on a heterogeneous nvdla+systolic pool. Emits
//! `BENCH_pipeline.json` at the repository root so the overlap
//! trajectory is tracked.
//!
//! The acceptance bar this guards: tile-level pipelining >= 1.3x over
//! pipelining-off on the 2-accelerator VGG16 run, with work totals
//! (DRAM traffic) unchanged.

use smaug::api::{Report, Session, Soc};
use smaug::config::AccelKind;
use smaug::util::{fmt_ns, JsonWriter};
use std::path::Path;

const NET: &str = "vgg16";

fn run(pool: &[AccelKind], mode: &str) -> anyhow::Result<Report> {
    let mut soc = Soc::builder();
    for &k in pool {
        soc = soc.accel(k);
    }
    let mut s = Session::on(soc.build()).network(NET);
    s = match mode {
        "off" => s.pipeline(false),
        "op" => s.pipeline(true),
        "tile" => s.tile_pipeline(true),
        other => unreachable!("unknown mode {other}"),
    };
    s.run()
}

fn main() -> anyhow::Result<()> {
    println!("pipeline_overlap — {NET}: off vs op-level vs tile-level pipelining");
    println!(
        "{:<18} {:<6} {:>12} {:>9} {:>9} {:>9}",
        "pool", "mode", "latency", "speedup", "overlap", "cpu busy"
    );
    let pools: &[(&str, Vec<AccelKind>)] = &[
        ("2x nvdla", vec![AccelKind::Nvdla, AccelKind::Nvdla]),
        ("nvdla+systolic", vec![AccelKind::Nvdla, AccelKind::Systolic]),
    ];
    let mut w = JsonWriter::new();
    w.begin_object();
    w.key("bench").string("pipeline_overlap");
    w.key("network").string(NET);
    w.key("rows").begin_array();
    let mut headline = 0.0f64;
    for (pool_name, pool) in pools {
        let mut off_ns = 0.0f64;
        let mut off_bytes = 0u64;
        for mode in ["off", "op", "tile"] {
            let rep = run(pool, mode)?;
            if mode == "off" {
                off_ns = rep.total_ns;
                off_bytes = rep.dram_bytes;
            } else {
                assert_eq!(
                    rep.dram_bytes, off_bytes,
                    "{pool_name}/{mode}: overlap must not change traffic"
                );
            }
            let speedup = off_ns / rep.total_ns.max(1e-12);
            if *pool_name == "2x nvdla" && mode == "tile" {
                headline = speedup;
            }
            let p = rep.pipeline.as_ref().expect("single runs report pipeline");
            println!(
                "{:<18} {:<6} {:>12} {:>8.2}x {:>8.1}% {:>8.1}%",
                pool_name,
                mode,
                fmt_ns(rep.total_ns),
                speedup,
                100.0 * p.overlap_frac,
                100.0 * p.cpu_occupancy
            );
            w.begin_object();
            w.key("pool").string(pool_name);
            w.key("mode").string(mode);
            w.key("total_ns").number(rep.total_ns);
            w.key("speedup_vs_off").number(speedup);
            w.key("overlap_frac").number(p.overlap_frac);
            w.key("cpu_occupancy").number(p.cpu_occupancy);
            w.key("accel_occupancy").begin_array();
            for &o in &p.accel_occupancy {
                w.number(o);
            }
            w.end_array();
            w.key("dram_bytes").uint(rep.dram_bytes);
            w.end_object();
        }
    }
    w.end_array();
    w.key("speedup_tile_vs_off").number(headline);
    w.end_object();
    let out = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package dir has a parent")
        .join("BENCH_pipeline.json");
    std::fs::write(&out, w.finish())?;
    println!(
        "headline: {headline:.2}x tile vs off on 2x nvdla (target >= 1.3x)\nwrote {}",
        out.display()
    );
    // Unlike host-wall-clock benches, this speedup is simulated time —
    // deterministic — so missing the bar is a hard failure CI can see.
    if headline < 1.3 {
        eprintln!("FAIL: {headline:.2}x is below the 1.3x acceptance bar");
        std::process::exit(1);
    }
    Ok(())
}
