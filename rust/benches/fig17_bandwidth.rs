//! Bench harness for paper Fig 17: DRAM bandwidth utilization during the
//! data preparation/gathering phases, 1 vs 8 threads (paper: ~2.7x on
//! ResNet50; small nets like Minerva gain little) — extended with a
//! routed-topology sweep: the same software-phase utilization metric
//! across `--dram-channels 1,2,4`, showing how interleaving spreads the
//! tiling-copy traffic the figure measures.

use smaug::api::{Session, Soc};
use smaug::config::AccelKind;
use smaug::figures;

fn main() -> anyhow::Result<()> {
    let rows = figures::fig16(&["minerva", "cnn10", "vgg16", "elu24", "resnet50"], &[1, 8])?;
    figures::print_fig17(&rows);

    // Channel sweep: per-channel occupancy of the same transfer stream.
    println!("\nmemsys — sw-phase DRAM utilization vs channel count (vgg16, 8 threads)");
    println!(
        "{:<9} {:>14} {:>14} {:>20}",
        "channels", "sw-phase util", "overall util", "per-channel busy"
    );
    for ch in [1usize, 2, 4] {
        let rep = Session::on(
            Soc::builder()
                .accels(AccelKind::Nvdla, 2)
                .dram_channels(ch)
                .build(),
        )
        .network("vgg16")
        .threads(8)
        .tile_pipeline(true)
        .run()?;
        let m = rep.memsys.as_ref().expect("single runs report memsys");
        println!(
            "{:<9} {:>13.1}% {:>13.1}% {:>20}",
            ch,
            100.0 * rep.sw_phase_dram_utilization,
            100.0 * rep.dram_utilization,
            m.busy_string()
        );
    }
    Ok(())
}
