//! Bench harness for paper Fig 17: DRAM bandwidth utilization during the
//! data preparation/gathering phases, 1 vs 8 threads (paper: ~2.7x on
//! ResNet50; small nets like Minerva gain little).

use smaug::figures;

fn main() -> anyhow::Result<()> {
    let rows = figures::fig16(&["minerva", "cnn10", "vgg16", "elu24", "resnet50"], &[1, 8])?;
    figures::print_fig17(&rows);
    Ok(())
}
