//! Bench harness for paper Fig 7/8: Aladdin-style loop-sampling
//! validation — exact vs maximally-sampled cycle estimates per kernel.

use smaug::figures;

fn main() {
    let rows = figures::fig08();
    figures::print_fig08(&rows);
}
