//! Ablation bench: the two scheduler extensions DESIGN.md calls out —
//! double buffering (the NVDLA convolution buffer the paper explicitly
//! does not model) and inter-accelerator reduction (the paper's §IV-B
//! future work) — individually and combined, across configurations.

use smaug::config::{SimOptions, SocConfig};
use smaug::nets;
use smaug::sim::Simulator;
use smaug::util::fmt_ns;

fn run(net: &str, opts: SimOptions) -> anyhow::Result<(f64, u64)> {
    let g = nets::build_network(net)?;
    let r = Simulator::new(SocConfig::default(), opts).run(&g)?;
    Ok((r.total_ns, r.dram_bytes))
}

fn main() -> anyhow::Result<()> {
    println!("Ablation — scheduler extensions (baseline: DMA, 1 thread)");
    println!(
        "{:<10} {:>3} {:>14} {:>14} {:>14} {:>14}",
        "net", "acc", "baseline", "+dbuf", "+inter-red", "+both"
    );
    for net in ["cnn10", "vgg16", "elu24"] {
        for accels in [1usize, 8] {
            let base = SimOptions {
                num_accels: accels,
                ..SimOptions::default()
            };
            let (t0, _) = run(net, base.clone())?;
            let (t1, _) = run(
                net,
                SimOptions {
                    double_buffer: true,
                    ..base.clone()
                },
            )?;
            let (t2, b2) = run(
                net,
                SimOptions {
                    inter_accel_reduction: true,
                    ..base.clone()
                },
            )?;
            let (t3, _) = run(
                net,
                SimOptions {
                    double_buffer: true,
                    inter_accel_reduction: true,
                    ..base.clone()
                },
            )?;
            println!(
                "{:<10} {:>3} {:>14} {:>13}{} {:>13}{} {:>13}{}",
                net,
                accels,
                fmt_ns(t0),
                fmt_ns(t1),
                mark(t0, t1),
                fmt_ns(t2),
                mark(t0, t2),
                fmt_ns(t3),
                mark(t0, t3),
            );
            let _ = b2;
        }
    }
    println!("  (* = >2% faster than baseline; inter-reduction trades extra");
    println!("   partial-sum traffic for pool utilization on starved layers)");
    Ok(())
}

fn mark(base: f64, v: f64) -> &'static str {
    if v < base * 0.98 {
        "*"
    } else {
        " "
    }
}
