//! Ablation bench: the two scheduler extensions DESIGN.md calls out —
//! double buffering (the NVDLA convolution buffer the paper explicitly
//! does not model) and inter-accelerator reduction (the paper's §IV-B
//! future work) — individually and combined, across configurations,
//! driven through the scenario API.

use smaug::api::{Session, Soc};
use smaug::config::AccelKind;
use smaug::util::fmt_ns;

fn run(net: &str, accels: usize, dbuf: bool, inter: bool) -> anyhow::Result<(f64, u64)> {
    let r = Session::on(Soc::builder().accels(AccelKind::Nvdla, accels).build())
        .network(net)
        .double_buffer(dbuf)
        .inter_accel_reduction(inter)
        .run()?;
    Ok((r.total_ns, r.dram_bytes))
}

fn main() -> anyhow::Result<()> {
    println!("Ablation — scheduler extensions (baseline: DMA, 1 thread)");
    println!(
        "{:<10} {:>3} {:>14} {:>14} {:>14} {:>14}",
        "net", "acc", "baseline", "+dbuf", "+inter-red", "+both"
    );
    for net in ["cnn10", "vgg16", "elu24"] {
        for accels in [1usize, 8] {
            let (t0, _) = run(net, accels, false, false)?;
            let (t1, _) = run(net, accels, true, false)?;
            let (t2, b2) = run(net, accels, false, true)?;
            let (t3, _) = run(net, accels, true, true)?;
            println!(
                "{:<10} {:>3} {:>14} {:>13}{} {:>13}{} {:>13}{}",
                net,
                accels,
                fmt_ns(t0),
                fmt_ns(t1),
                mark(t0, t1),
                fmt_ns(t2),
                mark(t0, t2),
                fmt_ns(t3),
                mark(t0, t3),
            );
            let _ = b2;
        }
    }
    println!("  (* = >2% faster than baseline; inter-reduction trades extra");
    println!("   partial-sum traffic for pool utilization on starved layers)");
    Ok(())
}

fn mark(base: f64, v: f64) -> &'static str {
    if v < base * 0.98 {
        "*"
    } else {
        " "
    }
}
