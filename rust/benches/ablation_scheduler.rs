//! Ablation bench: scheduler decisions, driven through the
//! policy-tournament framework. Section 1 races the pluggable policies
//! (fifo / heft / rr) on homogeneous and heterogeneous pools and
//! hard-fails if any policy loses work or loses to the serial schedule.
//! Section 2 keeps the two scheduler extensions DESIGN.md calls out —
//! double buffering and inter-accelerator reduction — as a baseline-vs-on
//! table.

use smaug::api::{policy_tournament, Session, Soc};
use smaug::config::{AccelKind, Policy};
use smaug::util::fmt_ns;

fn run(net: &str, accels: usize, dbuf: bool, inter: bool) -> anyhow::Result<(f64, u64)> {
    let r = Session::on(Soc::builder().accels(AccelKind::Nvdla, accels).build())
        .network(net)
        .double_buffer(dbuf)
        .inter_accel_reduction(inter)
        .run()?;
    Ok((r.total_ns, r.dram_bytes))
}

fn main() -> anyhow::Result<()> {
    let policies = [Policy::Fifo, Policy::Heft, Policy::Rr];
    println!("Ablation — scheduler policies (tile-pipelined vs serial)");
    for net in ["cnn10", "vgg16"] {
        for (label, soc) in [
            ("2x nvdla", Soc::builder().accels(AccelKind::Nvdla, 2).build()),
            (
                "nvdla+systolic",
                Soc::builder()
                    .accel(AccelKind::Nvdla)
                    .accel(AccelKind::Systolic)
                    .build(),
            ),
        ] {
            let t = policy_tournament(&Session::on(soc).network(net), &policies, 4)?;
            println!("\n{net} on {label}");
            println!("{}", t.summary());
            assert_eq!(
                t.work_conserving(),
                policies.len(),
                "a policy reordered work into different DRAM traffic"
            );
            assert_eq!(
                t.dominating(),
                policies.len(),
                "a policy lost to the serial schedule"
            );
        }
    }

    println!("\nAblation — scheduler extensions (baseline: DMA, 1 thread)");
    println!(
        "{:<10} {:>3} {:>14} {:>14} {:>14} {:>14}",
        "net", "acc", "baseline", "+dbuf", "+inter-red", "+both"
    );
    for net in ["cnn10", "vgg16", "elu24"] {
        for accels in [1usize, 8] {
            let (t0, _) = run(net, accels, false, false)?;
            let (t1, _) = run(net, accels, true, false)?;
            let (t2, _) = run(net, accels, false, true)?;
            let (t3, _) = run(net, accels, true, true)?;
            println!(
                "{:<10} {:>3} {:>14} {:>13}{} {:>13}{} {:>13}{}",
                net,
                accels,
                fmt_ns(t0),
                fmt_ns(t1),
                mark(t0, t1),
                fmt_ns(t2),
                mark(t0, t2),
                fmt_ns(t3),
                mark(t0, t3),
            );
        }
    }
    println!("  (* = >2% faster than baseline; inter-reduction trades extra");
    println!("   partial-sum traffic for pool utilization on starved layers)");
    Ok(())
}

fn mark(base: f64, v: f64) -> &'static str {
    if v < base * 0.98 {
        "*"
    } else {
        " "
    }
}
