//! Bench harness for paper Fig 5/6: memcpy cost of different tiling
//! strategies on the paper's medium and large NHWC tensors.

use smaug::figures;

fn main() {
    let rows = figures::fig06();
    figures::print_fig06(&rows);
}
