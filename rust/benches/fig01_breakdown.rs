//! Bench harness for paper Fig 1: end-to-end latency breakdown on the
//! baseline SoC (1x NVDLA, DMA, single-threaded software stack) across
//! the full network zoo. Run with `cargo bench --bench fig01_breakdown`.

use smaug::figures;
use smaug::nets::ALL_NETWORKS;

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let rows = figures::fig01(ALL_NETWORKS)?;
    figures::print_fig01(&rows);
    println!("(harness wall-clock: {:.2?})", t0.elapsed());
    Ok(())
}
