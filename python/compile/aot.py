"""AOT pipeline: lower the L2 model (with its L1 Pallas kernels) to HLO text.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >=
0.5 emits protos with 64-bit instruction ids which the published ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Outputs, under ``--out-dir`` (default ``artifacts/``):

  gemm_m{M}_k{K}_n{N}_{variant}.hlo.txt   one per canonical tile shape
  manifest.txt                            one line per artifact:
      gemm <M> <K> <N> <variant> <relative-path>

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated m,k,n,variant filter for quick rebuilds",
    )
    args = ap.parse_args(argv)
    os.makedirs(args.out_dir, exist_ok=True)

    only = None
    if args.only:
        parts = args.only.split(",")
        only = (int(parts[0]), int(parts[1]), int(parts[2]), parts[3])

    manifest_lines = []
    count = 0
    for m, k, n, variant in model.canonical_shapes():
        if only is not None and (m, k, n, variant) != only:
            continue
        name = f"gemm_m{m}_k{k}_n{n}_{variant}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        lowered = model.lower_tile(m, k, n, variant)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"gemm {m} {k} {n} {variant} {name}")
        count += 1
        if count % 16 == 0:
            print(f"  ... {count} artifacts", file=sys.stderr)

    manifest_path = os.path.join(args.out_dir, "manifest.txt")
    with open(manifest_path, "w") as f:
        f.write("# kind M K N variant path\n")
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {count} HLO artifacts + manifest to {args.out_dir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
