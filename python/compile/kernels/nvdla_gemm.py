"""Layer-1 Pallas kernel: the NVDLA convolution-engine dataflow as a GEMM tile.

The NVDLA-inspired engine in SMAUG (paper Fig. 4) is built from 8 PEs, each
a 32-way multiply-accumulate array that reduces partial products across a
32-element *channel block* per cycle, with weights register-resident
(L0 weight-stationary) and inputs/outputs SRAM-resident (L1 input/output
stationary).  After im2col, a convolution tile is exactly a GEMM

    out[M, N] = A[M, K] @ W[K, N]      M = out rows*cols of the tile
                                       K = R*S*C_tile (reduced channel dim)
                                       N = output channels of the tile

and the NVDLA dataflow is a K-blocked accumulation with block size 32.

Hardware adaptation (TPU-style, per DESIGN.md §Hardware-Adaptation): the
paper's DRAM->scratchpad tiling becomes the BlockSpec HBM->VMEM schedule;
the 32-wide channel reduction becomes the innermost contraction block; the
8-PE output-channel parallelism is the kernel grid's N dimension.  The
kernel is lowered with ``interpret=True`` (CPU PJRT cannot run Mosaic
custom-calls); on a real TPU the same kernel maps the contraction onto the
MXU.

Functional note: SMAUG's hardware uses 16-bit fixed point with 32-bit
accumulation.  We compute in f32 (accumulate in f32) and model the 16-bit
datapath in the Rust timing/energy models; numerics here are the
*functional* reference semantics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The NVDLA MACC array reduces 32 channel elements per PE per cycle; the
# kernel accumulates over K in blocks of this size.
CHANNEL_BLOCK = 32


def _nvdla_gemm_kernel(a_ref, w_ref, o_ref):
    """K-blocked accumulating GEMM kernel body (grid = K / CHANNEL_BLOCK)."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _nvdla_gemm_bias_act_kernel(a_ref, w_ref, b_ref, o_ref, *, activation):
    """Fused GEMM + bias + activation (SMAUG fuses conv + element-wise)."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(0) == pl.num_programs(0) - 1)
    def _finish():
        acc = o_ref[...] + b_ref[...]
        if activation == "relu":
            acc = jnp.maximum(acc, 0.0)
        elif activation == "none":
            pass
        else:  # pragma: no cover - guarded by caller
            raise ValueError(f"unknown activation {activation}")
        o_ref[...] = acc


def _kblock(k: int) -> int:
    """Channel-block size: 32 when K allows it, else the whole of K."""
    if k % CHANNEL_BLOCK == 0:
        return CHANNEL_BLOCK
    return k


def nvdla_gemm(a: jax.Array, w: jax.Array, *, interpret: bool = True) -> jax.Array:
    """``a[M,K] @ w[K,N]`` via the NVDLA-dataflow Pallas kernel."""
    m, k = a.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    kb = _kblock(k)
    grid = (k // kb,)
    return pl.pallas_call(
        _nvdla_gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, kb), lambda i: (0, i)),
            pl.BlockSpec((kb, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((m, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, w)


def nvdla_gemm_bias_act(
    a: jax.Array,
    w: jax.Array,
    bias: jax.Array,
    *,
    activation: str = "relu",
    interpret: bool = True,
) -> jax.Array:
    """Fused ``act(a @ w + bias)`` via the NVDLA-dataflow Pallas kernel.

    ``bias`` has shape ``(1, N)`` and is broadcast over rows, matching the
    per-output-channel bias of a convolution layer.
    """
    m, k = a.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert bias.shape == (1, n), f"bias shape {bias.shape} != (1, {n})"
    kb = _kblock(k)
    grid = (k // kb,)
    kernel = functools.partial(_nvdla_gemm_bias_act_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, kb), lambda i: (0, i)),
            pl.BlockSpec((kb, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, w, bias)


def vmem_footprint_bytes(m: int, k: int, n: int, elem_bytes: int = 4) -> int:
    """Estimated VMEM-resident bytes for one grid step of the kernel.

    Mirrors the paper's three-scratchpad budget (inputs, weights, outputs,
    32 KB each): one A block (m x kb), one W block (kb x n), and the
    accumulating output block (m x n).
    """
    kb = _kblock(k)
    return elem_bytes * (m * kb + kb * n + m * n)
