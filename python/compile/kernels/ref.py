"""Pure-jnp oracles for the Pallas kernels and the L2 model ops.

Everything here is deliberately written with plain jnp / lax primitives
(no Pallas) so pytest can compare kernel output against an independent
implementation.  These are also the semantics the Rust reference executor
(`rust/src/refexec/`) mirrors, so the whole stack shares one functional
contract.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def gemm(a: jax.Array, w: jax.Array) -> jax.Array:
    """Plain ``a[M,K] @ w[K,N]`` in f32."""
    return jnp.dot(
        a.astype(jnp.float32), w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def gemm_bias_act(
    a: jax.Array, w: jax.Array, bias: jax.Array, activation: str = "relu"
) -> jax.Array:
    out = gemm(a, w) + bias.astype(jnp.float32)
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation}")
    return out


def conv2d_nhwc(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
) -> jax.Array:
    """NHWC convolution; ``w`` is ``(K, R, S, C)`` (SMAUG's weight layout)."""
    # lax wants HWIO for rhs.
    w_hwio = jnp.transpose(w, (1, 2, 3, 0))
    return lax.conv_general_dilated(
        x.astype(jnp.float32),
        w_hwio.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def max_pool_nhwc(x: jax.Array, size: int = 2, stride: int = 2) -> jax.Array:
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, size, size, 1),
        window_strides=(1, stride, stride, 1),
        padding="VALID",
    )


def avg_pool_nhwc(x: jax.Array, size: int, stride: int) -> jax.Array:
    summed = lax.reduce_window(
        x,
        0.0,
        lax.add,
        window_dimensions=(1, size, size, 1),
        window_strides=(1, stride, stride, 1),
        padding="VALID",
    )
    return summed / float(size * size)


def batch_norm_nhwc(
    x: jax.Array,
    mean: jax.Array,
    var: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    eps: float = 1e-5,
) -> jax.Array:
    inv = gamma / jnp.sqrt(var + eps)
    return x * inv + (beta - mean * inv)


def im2col_nhwc(
    x: jax.Array, r: int, s: int, *, stride: int = 1, padding: str = "SAME"
) -> jax.Array:
    """Unfold an NHWC image into the ``(M, K)`` GEMM operand.

    M = N*H_out*W_out rows, K = r*s*C columns, ordered (kr, kc, c) to match
    the NVDLA weight layout — the same transform SMAUG's software stack
    performs during data preparation.
    """
    n, h, w, c = x.shape
    if padding == "SAME":
        out_h = -(-h // stride)
        out_w = -(-w // stride)
        pad_h = max((out_h - 1) * stride + r - h, 0)
        pad_w = max((out_w - 1) * stride + s - w, 0)
        x = jnp.pad(
            x,
            (
                (0, 0),
                (pad_h // 2, pad_h - pad_h // 2),
                (pad_w // 2, pad_w - pad_w // 2),
                (0, 0),
            ),
        )
    elif padding == "VALID":
        out_h = (h - r) // stride + 1
        out_w = (w - s) // stride + 1
    else:
        raise ValueError(padding)
    cols = []
    for kr in range(r):
        for kc in range(s):
            patch = lax.dynamic_slice(
                x,
                (0, kr, kc, 0),
                (n, (out_h - 1) * stride + 1, (out_w - 1) * stride + 1, c),
            )
            patch = patch[:, ::stride, ::stride, :]
            cols.append(patch.reshape(n * out_h * out_w, c))
    # Interleave so each row is ordered (kr, kc, c) fastest-to-slowest = c.
    return jnp.concatenate(cols, axis=1)


def conv2d_via_gemm(
    x: jax.Array, w: jax.Array, *, stride: int = 1, padding: str = "SAME"
) -> jax.Array:
    """Convolution through im2col + GEMM — validates the lowering the Rust
    scheduler uses on the accelerator path."""
    k, r, s, c = w.shape
    n, h, wid, _ = x.shape
    a = im2col_nhwc(x, r, s, stride=stride, padding=padding)
    w_mat = jnp.transpose(w.reshape(k, r * s * c))  # (K_gemm, N=k)
    out = gemm(a, w_mat)
    if padding == "SAME":
        out_h = -(-h // stride)
        out_w = -(-wid // stride)
    else:
        out_h = (h - r) // stride + 1
        out_w = (wid - s) // stride + 1
    return out.reshape(n, out_h, out_w, k)
