"""Layer-2 JAX model: the operator library SMAUG's accelerator path executes.

Each function here is the compute graph for one *canonical accelerator
tile*: the Rust scheduler (L3) im2cols a convolution tile, pads it to the
nearest canonical (M, K, N), and executes the matching AOT-compiled HLO on
the PJRT CPU client.  All functions call the L1 Pallas kernel so the NVDLA
dataflow lowers into the artifact.

This module is build-time only: `aot.py` lowers it once into
``artifacts/*.hlo.txt`` and Python never runs on the simulation path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import nvdla_gemm as knl

# Canonical tile grid.  The tiling optimizer in Rust guarantees tiles fit
# the paper's 32 KB scratchpads (<= 16 Ki 16-bit elements per operand), so
# after im2col: M = out rows*cols <= 1024, K = R*S*C_tile <= 2048,
# N = out channels <= 256.  Rust pads each tile up to the nearest entry.
CANONICAL_M = (16, 64, 256, 1024)
CANONICAL_K = (32, 128, 512, 2048)
CANONICAL_N = (16, 64, 256)
VARIANTS = ("none", "relu")  # fused epilogue: plain, or +bias+relu


def gemm_tile(a: jax.Array, w: jax.Array) -> tuple[jax.Array]:
    """Plain accelerator GEMM tile (partial-product tiles, no epilogue)."""
    return (knl.nvdla_gemm(a, w),)


def gemm_tile_bias_relu(
    a: jax.Array, w: jax.Array, bias: jax.Array
) -> tuple[jax.Array]:
    """Fused GEMM + bias + ReLU tile (SMAUG's conv+elementwise fusion)."""
    return (knl.nvdla_gemm_bias_act(a, w, bias, activation="relu"),)


def lower_tile(m: int, k: int, n: int, variant: str):
    """Lower one canonical tile to a jax ``Lowered`` object."""
    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    w = jax.ShapeDtypeStruct((k, n), jnp.float32)
    if variant == "none":
        return jax.jit(gemm_tile).lower(a, w)
    if variant == "relu":
        b = jax.ShapeDtypeStruct((1, n), jnp.float32)
        return jax.jit(gemm_tile_bias_relu).lower(a, w, b)
    raise ValueError(f"unknown variant {variant}")


def canonical_shapes():
    """Yield every (m, k, n, variant) in the artifact grid."""
    for m in CANONICAL_M:
        for k in CANONICAL_K:
            for n in CANONICAL_N:
                for v in VARIANTS:
                    yield m, k, n, v


def round_up(value: int, grid: tuple[int, ...]) -> int:
    """Round ``value`` up to the nearest grid entry (mirrors Rust side)."""
    for g in grid:
        if value <= g:
            return g
    raise ValueError(f"{value} exceeds canonical grid max {grid[-1]}")
