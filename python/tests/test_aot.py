"""AOT pipeline: the artifact grid must stay consistent with the Rust
runtime's canonical grids, and emitted HLO must be loadable text."""

import os

import pytest

from compile import aot, model

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

# Mirror of rust/src/runtime/manifest.rs — a drift here breaks the
# runtime's padding contract.
RUST_CANONICAL_M = (16, 64, 256, 1024)
RUST_CANONICAL_K = (32, 128, 512, 2048)
RUST_CANONICAL_N = (16, 64, 256)


def test_grids_match_rust_runtime():
    assert tuple(model.CANONICAL_M) == RUST_CANONICAL_M
    assert tuple(model.CANONICAL_K) == RUST_CANONICAL_K
    assert tuple(model.CANONICAL_N) == RUST_CANONICAL_N


def test_variants_match_manifest_vocabulary():
    assert set(model.VARIANTS) == {"none", "relu"}


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.txt")),
    reason="run `make artifacts` first",
)
def test_manifest_covers_full_grid():
    with open(os.path.join(ART_DIR, "manifest.txt")) as f:
        lines = [
            l.split() for l in f if l.strip() and not l.startswith("#")
        ]
    entries = {(int(m), int(k), int(n), v) for _, m, k, n, v, _ in lines}
    expected = {
        (m, k, n, v) for m, k, n, v in model.canonical_shapes()
    }
    assert entries == expected
    # Every referenced file exists and looks like HLO text.
    for _, _, _, _, _, path in lines[:8]:
        full = os.path.join(ART_DIR, path)
        assert os.path.exists(full), path
        with open(full) as f:
            head = f.read(200)
        assert "HloModule" in head, path


def test_single_artifact_lowering_roundtrip(tmp_path):
    rc = aot.main(["--out-dir", str(tmp_path), "--only", "16,32,16,relu"])
    assert rc == 0
    files = os.listdir(tmp_path)
    assert "manifest.txt" in files
    assert "gemm_m16_k32_n16_relu.hlo.txt" in files
    text = (tmp_path / "gemm_m16_k32_n16_relu.hlo.txt").read_text()
    assert "HloModule" in text and "f32[16,32]" in text
