"""L1 correctness: Pallas NVDLA-dataflow kernel vs the pure-jnp oracle.

This is the core correctness signal of the compile path: every artifact the
Rust runtime executes comes from these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import nvdla_gemm as knl
from compile.kernels import ref


def _rand(shape, seed, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


# ---------------------------------------------------------------- basic


@pytest.mark.parametrize("m,k,n", [(16, 32, 16), (64, 128, 64), (8, 96, 24)])
def test_gemm_matches_ref(m, k, n):
    a, w = _rand((m, k), 0), _rand((k, n), 1)
    got = knl.nvdla_gemm(a, w)
    want = ref.gemm(a, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n", [(16, 64, 16), (32, 32, 8)])
@pytest.mark.parametrize("activation", ["relu", "none"])
def test_gemm_bias_act_matches_ref(m, k, n, activation):
    a, w, b = _rand((m, k), 2), _rand((k, n), 3), _rand((1, n), 4)
    got = knl.nvdla_gemm_bias_act(a, w, b, activation=activation)
    want = ref.gemm_bias_act(a, w, b, activation=activation)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_k_not_multiple_of_32_single_block():
    # K not divisible by the channel block degrades to one K block.
    a, w = _rand((8, 49), 5), _rand((49, 8), 6)
    np.testing.assert_allclose(
        knl.nvdla_gemm(a, w), ref.gemm(a, w), rtol=1e-5, atol=1e-5
    )


def test_relu_clamps_negative():
    a = -jnp.ones((4, 32), jnp.float32)
    w = jnp.ones((32, 4), jnp.float32)
    b = jnp.zeros((1, 4), jnp.float32)
    out = knl.nvdla_gemm_bias_act(a, w, b, activation="relu")
    assert float(jnp.max(out)) == 0.0


def test_accumulation_over_many_channel_blocks():
    # 16 channel blocks: exercises init-at-first / epilogue-at-last logic.
    a, w = _rand((4, 512), 7), _rand((512, 4), 8)
    np.testing.assert_allclose(
        knl.nvdla_gemm(a, w), ref.gemm(a, w), rtol=1e-4, atol=1e-4
    )


def test_identity_weight_roundtrip():
    a = _rand((8, 32), 9)
    w = jnp.eye(32, dtype=jnp.float32)
    np.testing.assert_allclose(knl.nvdla_gemm(a, w), a, rtol=1e-6, atol=1e-6)


def test_vmem_footprint_estimate():
    # Per-grid-step footprint only ever holds one 32-wide K block of A and W
    # plus the accumulating output block — never the full K extent.
    assert knl.vmem_footprint_bytes(64, 2048, 64) == 4 * (
        64 * 32 + 32 * 64 + 64 * 64
    )
    # A *real* (unpadded) tile respecting the paper's per-operand scratchpad
    # budget (<= 16 Ki 16-bit elems for in/wgt/out) always fits 3 x 32 KB:
    # worst case m*kb, kb*n, m*n are each <= the operand that contains them.
    m, k_t, n = 128, 9 * 128, 128  # H_o*W_o=128, R*S*C=1152, K_t=128
    assert m * n <= 16384 or True  # output tile budget checked in Rust tiling
    assert knl.vmem_footprint_bytes(m, k_t, n, elem_bytes=2) <= 3 * 32 * 1024


# ---------------------------------------------------------------- hypothesis

dims_m = st.integers(1, 12).map(lambda i: 4 * i)
dims_k = st.sampled_from([16, 32, 64, 96, 128, 160, 49, 27])
dims_n = st.integers(1, 8).map(lambda i: 4 * i)


@settings(max_examples=25, deadline=None)
@given(m=dims_m, k=dims_k, n=dims_n, seed=st.integers(0, 2**16))
def test_gemm_shape_sweep(m, k, n, seed):
    a, w = _rand((m, k), seed), _rand((k, n), seed + 1)
    got = knl.nvdla_gemm(a, w)
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, ref.gemm(a, w), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    m=dims_m,
    k=st.sampled_from([32, 64, 128]),
    n=dims_n,
    seed=st.integers(0, 2**16),
    activation=st.sampled_from(["relu", "none"]),
)
def test_fused_shape_sweep(m, k, n, seed, activation):
    a, w, b = _rand((m, k), seed), _rand((k, n), seed + 1), _rand((1, n), seed + 2)
    got = knl.nvdla_gemm_bias_act(a, w, b, activation=activation)
    want = ref.gemm_bias_act(a, w, b, activation=activation)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**16),
)
def test_gemm_dtype_sweep(dtype, seed):
    # Inputs in reduced precision, accumulation still f32 (the NVDLA engine
    # accumulates 16-bit products in 32-bit).
    a = _rand((16, 64), seed, dtype).astype(jnp.float32)
    w = _rand((64, 16), seed + 1, dtype).astype(jnp.float32)
    got = knl.nvdla_gemm(a, w)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, ref.gemm(a, w), rtol=1e-2, atol=1e-2)
