"""L2 model: canonical-shape grid, rounding contract, and HLO lowering."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile import aot
from compile.kernels import ref


def test_canonical_grid_size():
    shapes = list(model.canonical_shapes())
    expected = (
        len(model.CANONICAL_M)
        * len(model.CANONICAL_K)
        * len(model.CANONICAL_N)
        * len(model.VARIANTS)
    )
    assert len(shapes) == expected
    assert len(set(shapes)) == expected


def test_round_up_exact_and_between():
    assert model.round_up(16, model.CANONICAL_M) == 16
    assert model.round_up(17, model.CANONICAL_M) == 64
    assert model.round_up(1, model.CANONICAL_M) == 16
    assert model.round_up(1024, model.CANONICAL_M) == 1024
    with pytest.raises(ValueError):
        model.round_up(4096, model.CANONICAL_M)


def test_grid_covers_scratchpad_tiles():
    # Any tile respecting the 32KB/16-bit scratchpad budget must round into
    # the grid: M <= 1024, K <= 2048, N <= 256 (DESIGN.md).
    model.round_up(1024, model.CANONICAL_M)
    model.round_up(2048, model.CANONICAL_K)
    model.round_up(256, model.CANONICAL_N)


def test_lower_tile_produces_hlo_text():
    lowered = model.lower_tile(16, 32, 16, "none")
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[16,32]" in text
    assert "f32[32,16]" in text


def test_lower_fused_tile_has_bias_param():
    lowered = model.lower_tile(16, 32, 16, "relu")
    text = aot.to_hlo_text(lowered)
    assert "f32[1,16]" in text  # bias parameter present


def test_lower_tile_rejects_unknown_variant():
    with pytest.raises(ValueError):
        model.lower_tile(16, 32, 16, "gelu")


def test_gemm_tile_numerics():
    a = jnp.ones((16, 32), jnp.float32)
    w = jnp.ones((32, 16), jnp.float32) * 0.5
    (out,) = model.gemm_tile(a, w)
    np.testing.assert_allclose(out, ref.gemm(a, w), rtol=1e-6)


def test_fused_tile_numerics():
    a = jnp.ones((16, 32), jnp.float32) * -1.0
    w = jnp.ones((32, 16), jnp.float32)
    b = jnp.full((1, 16), 5.0, jnp.float32)
    (out,) = model.gemm_tile_bias_relu(a, w, b)
    # -32 + 5 = -27 -> relu -> 0
    np.testing.assert_allclose(out, jnp.zeros((16, 16)))
