"""Oracle self-consistency: conv-via-GEMM (the accelerator lowering the Rust
scheduler uses) must equal direct lax convolution, plus pool/BN semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def _rand(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


@pytest.mark.parametrize(
    "h,w,c,k,r,stride,padding",
    [
        (8, 8, 4, 8, 3, 1, "SAME"),
        (16, 16, 8, 16, 3, 1, "SAME"),
        (8, 8, 4, 8, 1, 1, "SAME"),
        (9, 9, 3, 6, 3, 2, "SAME"),
        (8, 8, 4, 8, 3, 1, "VALID"),
        (32, 32, 3, 8, 3, 2, "SAME"),
    ],
)
def test_conv_via_gemm_matches_lax(h, w, c, k, r, stride, padding):
    x = _rand((1, h, w, c), 0)
    wt = _rand((k, r, r, c), 1)
    got = ref.conv2d_via_gemm(x, wt, stride=stride, padding=padding)
    want = ref.conv2d_nhwc(x, wt, stride=stride, padding=padding)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(4, 20),
    c=st.sampled_from([1, 3, 4, 8]),
    k=st.sampled_from([2, 4, 8]),
    r=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**16),
)
def test_conv_via_gemm_property(h, c, k, r, stride, seed):
    x = _rand((1, h, h, c), seed)
    wt = _rand((k, r, r, c), seed + 1)
    got = ref.conv2d_via_gemm(x, wt, stride=stride, padding="SAME")
    want = ref.conv2d_nhwc(x, wt, stride=stride, padding="SAME")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_im2col_dimensions():
    x = _rand((1, 8, 8, 4), 2)
    a = ref.im2col_nhwc(x, 3, 3, stride=1, padding="SAME")
    assert a.shape == (64, 36)


def test_im2col_1x1_is_reshape():
    x = _rand((1, 6, 6, 8), 3)
    a = ref.im2col_nhwc(x, 1, 1, stride=1, padding="SAME")
    np.testing.assert_allclose(a, x.reshape(36, 8))


def test_max_pool():
    x = jnp.arange(16.0).reshape(1, 4, 4, 1)
    out = ref.max_pool_nhwc(x, 2, 2)
    np.testing.assert_allclose(out.reshape(-1), [5.0, 7.0, 13.0, 15.0])


def test_avg_pool():
    x = jnp.ones((1, 4, 4, 2))
    out = ref.avg_pool_nhwc(x, 2, 2)
    np.testing.assert_allclose(out, jnp.ones((1, 2, 2, 2)))


def test_batch_norm_identity():
    x = _rand((1, 4, 4, 8), 4)
    c = x.shape[-1]
    out = ref.batch_norm_nhwc(
        x, jnp.zeros(c), jnp.ones(c), jnp.ones(c), jnp.zeros(c), eps=0.0
    )
    np.testing.assert_allclose(out, x, rtol=1e-6)


def test_batch_norm_normalizes():
    x = _rand((1, 8, 8, 4), 5) * 3.0 + 2.0
    mean = jnp.mean(x, axis=(0, 1, 2))
    var = jnp.var(x, axis=(0, 1, 2))
    out = ref.batch_norm_nhwc(x, mean, var, jnp.ones(4), jnp.zeros(4))
    np.testing.assert_allclose(jnp.mean(out, axis=(0, 1, 2)), jnp.zeros(4), atol=1e-4)
    np.testing.assert_allclose(jnp.var(out, axis=(0, 1, 2)), jnp.ones(4), atol=1e-3)
