"""Deterministic stand-in for the tiny slice of `hypothesis` these tests
use: `given`, `settings`, and `strategies.{integers, sampled_from}` with
`.map`. Each `@given` test runs a fixed number of pseudo-random samples
drawn with a seeded PRNG, so failures are reproducible and no network
install is needed.
"""

import random
import sys
import types

_SAMPLES = 12


class _Strategy:
    """A sampleable value source with hypothesis' `.map` combinator."""

    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng):
        return self._sample(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._sample(rng)))


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(values):
    seq = list(values)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))])


def settings(*_args, **kwargs):
    """Decorator form only; records max_examples for the paired @given."""
    max_examples = kwargs.get("max_examples")

    def deco(fn):
        if max_examples is not None:
            fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strategies):
    def deco(fn):
        inner = fn

        def wrapper(*args, **kwargs):
            n = getattr(inner, "_fallback_max_examples", None) or _SAMPLES
            rng = random.Random(0xC0FFEE ^ hash(inner.__name__) & 0xFFFF)
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strategies.items()}
                inner(*args, **drawn, **kwargs)

        wrapper.__name__ = inner.__name__
        wrapper.__doc__ = inner.__doc__
        return wrapper

    return deco


def install():
    """Register fallback modules as `hypothesis` / `hypothesis.strategies`."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = integers
    strategies.sampled_from = sampled_from
    hyp.strategies = strategies
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strategies
