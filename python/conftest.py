"""Pytest bootstrap for the python/ tree.

Two environment repairs so the suite runs (or skips loudly) everywhere:

1. Put this directory on sys.path so `from compile import ...` resolves
   regardless of the pytest invocation directory.
2. If the `hypothesis` package is not installed, register a minimal
   deterministic fallback under the same module names: `@given` expands
   each property test into a fixed, seeded sample sweep instead of a
   search. Coverage is reduced but the core correctness signal still
   runs; a notice is printed so CI logs show which mode executed.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

try:  # pragma: no cover - trivial import probe
    import hypothesis  # noqa: F401
except ImportError:
    from _hypothesis_fallback import install as _install_hypothesis_fallback

    _install_hypothesis_fallback()
    print(
        "NOTE: hypothesis not installed; property tests run on a "
        "deterministic fallback sampler (python/_hypothesis_fallback.py)",
        file=sys.stderr,
    )
