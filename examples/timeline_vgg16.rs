//! Fig 14 reproduction: accelerator-utilization timeline of VGG16's last
//! ten layers on an 8-accelerator system.
//!
//! The paper's observations to look for in the output:
//! * layers whose reduction-group count is below 8 cannot fill the pool
//!   (in-place channel reduction pins a group to one command queue);
//! * after a conv finishes, a long CPU "data finalization" gap follows
//!   (gathering output tiles) before the next layer starts.
//!
//! Run: `cargo run --release --example timeline_vgg16`

use smaug::api::{Scenario, Session, Soc};
use smaug::config::AccelKind;
use smaug::util::fmt_ns;

fn main() -> anyhow::Result<()> {
    let report = Session::on(Soc::builder().accels(AccelKind::Nvdla, 8).build())
        .network("vgg16")
        .scenario(Scenario::Inference)
        .capture_timeline(true)
        .run()?;
    let timeline = report.timeline.as_ref().expect("timeline was captured");

    println!("VGG16, 8 accelerators, DMA, 1 sw thread\n");
    println!("{}", timeline.ascii_gantt(110));

    // Per-op utilization of the pool during each op's hardware phase.
    println!(
        "\n{:<10} {:>4} {:>8} {:>10} {:>12} {:>10}",
        "op", "tag", "groups", "tiles", "span", "pool util"
    );
    for op in report.ops.iter().filter(|o| o.tiles > 0) {
        let hw_t0 = op.start_ns + op.prep_ns;
        let hw_t1 = hw_t0 + op.accel_ns + op.transfer_ns;
        let util = timeline.accel_utilization(8, hw_t0, hw_t1);
        println!(
            "{:<10} {:>4} {:>8} {:>10} {:>12} {:>9.0}%",
            op.name,
            op.tag,
            op.reduce_groups,
            op.tiles,
            fmt_ns(op.span_ns()),
            util * 100.0
        );
    }
    println!("\ntotal: {}", fmt_ns(report.total_ns));

    // The Fig-14 phenomenon: at least one conv layer has < 8 reduction
    // groups and therefore cannot use the whole pool.
    let starved = report
        .ops
        .iter()
        .filter(|o| o.tag == "C" && o.reduce_groups > 0 && o.reduce_groups < 8)
        .count();
    println!(
        "layers unable to fill the 8-accelerator pool (reduce groups < 8): {starved}"
    );
    Ok(())
}
