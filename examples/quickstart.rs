//! Quickstart: build a small network with the declarative graph builder
//! (the Rust mirror of SMAUG's Python frontend, paper Fig 2), simulate a
//! forward pass through the scenario API, and print the unified report.
//!
//! Run: `cargo run --release --example quickstart`

use smaug::api::{Scenario, Session, Soc};
use smaug::config::{AccelKind, InterfaceKind};
use smaug::graph::{Activation, GraphBuilder, Padding};

fn main() -> anyhow::Result<()> {
    // The paper's Fig-2 example: a residual unit.
    let mut g = GraphBuilder::new("residual_unit");
    let input = g.input("input", 1, 32, 32, 8);
    let conv0 = g.conv("conv0", input, 64, 3, 1, Padding::Same, Some(Activation::Relu));
    let conv1 = g.conv("conv1", conv0, 8, 3, 1, Padding::Same, None);
    g.add("add", conv1, input, Some(Activation::Relu));
    let mut graph = g.build();
    graph.fuse(); // automatic conv + element-wise fusion
    println!("{}\n", graph.summary());

    // Baseline SoC (paper Table II): 1 NVDLA-style engine, DMA, 1 thread.
    let report = Session::on(Soc::default())
        .graph(graph.clone())
        .scenario(Scenario::Inference)
        .run()?;
    println!("{}\n", report.summary());
    println!("{}", report.per_op_table());

    // The paper's optimized configuration: ACP + 8 accels + 8 threads.
    let opt = Session::on(Soc::builder().accels(AccelKind::Nvdla, 8).build())
        .graph(graph)
        .interface(InterfaceKind::Acp)
        .threads(8)
        .run()?;
    println!(
        "optimized (ACP + 8 accels + 8 threads): {} ({:.2}x speedup)",
        smaug::util::fmt_ns(opt.total_ns),
        report.total_ns / opt.total_ns
    );
    Ok(())
}
