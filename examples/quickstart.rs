//! Quickstart: build a small network with the declarative graph builder
//! (the Rust mirror of SMAUG's Python frontend, paper Fig 2), simulate a
//! forward pass on the baseline SoC, and print the latency breakdown.
//!
//! Run: `cargo run --release --example quickstart`

use smaug::config::{SimOptions, SocConfig};
use smaug::graph::{Activation, GraphBuilder, Padding};
use smaug::sim::Simulator;

fn main() -> anyhow::Result<()> {
    // The paper's Fig-2 example: a residual unit.
    let mut g = GraphBuilder::new("residual_unit");
    let input = g.input("input", 1, 32, 32, 8);
    let conv0 = g.conv("conv0", input, 64, 3, 1, Padding::Same, Some(Activation::Relu));
    let conv1 = g.conv("conv1", conv0, 8, 3, 1, Padding::Same, None);
    g.add("add", conv1, input, Some(Activation::Relu));
    let mut graph = g.build();
    graph.fuse(); // automatic conv + element-wise fusion
    println!("{}\n", graph.summary());

    // Baseline SoC (paper Table II): 1 NVDLA-style engine, DMA, 1 thread.
    let sim = Simulator::new(SocConfig::default(), SimOptions::default());
    let report = sim.run(&graph)?;
    println!("{}\n", report.breakdown_table());
    println!("{}", report.per_op_table());

    // The paper's optimized configuration: ACP + 8 accels + 8 threads.
    let fast = Simulator::new(SocConfig::default(), SimOptions::optimized());
    let opt = fast.run(&graph)?;
    println!(
        "optimized (ACP + 8 accels + 8 threads): {} ({:.2}x speedup)",
        smaug::util::fmt_ns(opt.total_ns),
        report.total_ns / opt.total_ns
    );
    Ok(())
}
