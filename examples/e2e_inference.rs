//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Loads the AOT artifacts (L1 Pallas NVDLA-dataflow kernels wrapped by
//! the L2 JAX tile model, compiled once by `make artifacts`), then runs a
//! complete CNN10 single-batch inference *execution-driven*: every
//! accelerator GEMM tile is dispatched through the PJRT CPU client while
//! the L3 simulator models timing and energy. The tiled output is
//! validated against the direct reference executor — proving tiling,
//! halos, reduction groups, untiling, and the AOT numerics all compose.
//!
//! Run: `make artifacts && cargo run --release --example e2e_inference`

use smaug::api::{Scenario, Session, Soc};
use smaug::config::FunctionalMode;
use smaug::nets;
use smaug::util::fmt_ns;

fn main() -> anyhow::Result<()> {
    for (net, expect_classes) in [("lenet5", 10), ("cnn10", 10)] {
        println!("=== {net} — execution-driven inference through AOT artifacts ===");
        println!("{}", nets::build_network(net)?.summary());

        let t0 = std::time::Instant::now();
        let report = Session::on(Soc::default())
            .network(net)
            .scenario(Scenario::Inference)
            .functional(FunctionalMode::Pjrt)
            .run()?;
        let wall = t0.elapsed();

        println!("{}", report.summary());
        let f = report.functional.as_ref().expect("functional run requested");
        println!(
            "functional backend : {} (AOT Pallas artifacts via PJRT)",
            f.backend
        );
        println!(
            "composition check  : max |tiled - direct| = {:.3e}  {}",
            f.max_divergence,
            if f.max_divergence < 1e-3 { "OK" } else { "FAIL" }
        );
        assert!(f.max_divergence < 1e-3, "tiled execution diverged");
        assert_eq!(f.output.len(), expect_classes, "classifier head shape");
        // A classification head output: report the argmax like a real app.
        let (argmax, max) = f
            .output
            .iter()
            .enumerate()
            .fold((0, f32::MIN), |acc, (i, &v)| if v > acc.1 { (i, v) } else { acc });
        println!("predicted class    : {argmax} (logit {max:.4})");
        println!(
            "simulated latency  : {}   host wall-clock: {:.2?}\n",
            fmt_ns(report.total_ns),
            wall
        );
    }
    println!("e2e OK: all layers composed through the three-layer stack.");
    Ok(())
}
