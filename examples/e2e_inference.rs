//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Loads the AOT artifacts (L1 Pallas NVDLA-dataflow kernels wrapped by
//! the L2 JAX tile model, compiled once by `make artifacts`), then runs a
//! complete CNN10 single-batch inference *execution-driven*: every
//! accelerator GEMM tile is dispatched through the PJRT CPU client while
//! the L3 simulator models timing and energy. The tiled output is
//! validated against the direct reference executor — proving tiling,
//! halos, reduction groups, untiling, and the AOT numerics all compose.
//!
//! Run: `make artifacts && cargo run --release --example e2e_inference`

use smaug::config::{FunctionalMode, SimOptions, SocConfig};
use smaug::nets;
use smaug::sim::Simulator;
use smaug::util::fmt_ns;

fn main() -> anyhow::Result<()> {
    for (net, expect_classes) in [("lenet5", 10), ("cnn10", 10)] {
        println!("=== {net} — execution-driven inference through AOT artifacts ===");
        let graph = nets::build_network(net)?;
        println!("{}", graph.summary());

        let opts = SimOptions {
            functional: FunctionalMode::Pjrt,
            ..SimOptions::default()
        };
        let sim = Simulator::new(SocConfig::default(), opts);
        let t0 = std::time::Instant::now();
        let run = sim.run_functional(&graph, None)?;
        let wall = t0.elapsed();

        println!("{}", run.report.breakdown_table());
        println!(
            "functional backend : {} (AOT Pallas artifacts via PJRT)",
            run.backend
        );
        println!(
            "composition check  : max |tiled - direct| = {:.3e}  {}",
            run.max_divergence,
            if run.max_divergence < 1e-3 { "OK" } else { "FAIL" }
        );
        assert!(run.max_divergence < 1e-3, "tiled execution diverged");
        assert_eq!(run.output.data.len(), expect_classes);
        // A classification head output: report the argmax like a real app.
        let (argmax, max) = run
            .output
            .data
            .iter()
            .enumerate()
            .fold((0, f32::MIN), |acc, (i, &v)| if v > acc.1 { (i, v) } else { acc });
        println!("predicted class    : {argmax} (logit {max:.4})");
        println!(
            "simulated latency  : {}   host wall-clock: {:.2?}\n",
            fmt_ns(run.report.total_ns),
            wall
        );
    }
    println!("e2e OK: all layers composed through the three-layer stack.");
    Ok(())
}
