//! Serving mode: open-loop requests share one SoC on the event-driven
//! scheduler — per-request latency percentiles, goodput under an SLO,
//! and the multi-accelerator scaling the serial per-op loop cannot
//! express. Includes a heterogeneous pool (NVDLA + systolic side by
//! side) composed with the `SocBuilder`.
//!
//! Run: `cargo run --release --example serving`

use smaug::api::{Scenario, Session, Soc};
use smaug::config::{AccelKind, ServeOptions};
use smaug::util::fmt_ns;

fn main() -> anyhow::Result<()> {
    // Open-loop Poisson arrivals at 10k req/s with an SLO of 4x the
    // uncontended single-request latency.
    let mut serve = ServeOptions::poisson(8, 10_000.0);
    serve.slo_multiple = Some(4.0);
    let scenario = Scenario::Serving(serve);

    let mut baseline_rps = None;
    for accels in [1usize, 8] {
        let soc = Soc::builder().accels(AccelKind::Nvdla, accels).build();
        let report = Session::on(soc)
            .network("vgg16")
            .threads(8)
            .scenario(scenario.clone())
            .run()?;
        println!("=== {accels} accelerator(s) ===");
        println!("{}", report.summary());
        let rps = report.throughput_rps.unwrap_or(0.0);
        let base = *baseline_rps.get_or_insert(rps);
        println!(
            "p99 {}  |  {:.2}x throughput vs 1 accel\n",
            fmt_ns(report.latency.map(|l| l.p99_ns).unwrap_or(0.0)),
            rps / base
        );
    }

    // Heterogeneous pool: two NVDLA engines plus two systolic arrays in
    // one SoC, all serving the same request stream.
    let soc = Soc::builder()
        .accels(AccelKind::Nvdla, 2)
        .accels(AccelKind::Systolic, 2)
        .build();
    let report = Session::on(soc)
        .network("vgg16")
        .threads(8)
        .scenario(scenario)
        .run()?;
    println!("=== heterogeneous pool (2x nvdla + 2x systolic) ===");
    println!("{}", report.summary());
    Ok(())
}
