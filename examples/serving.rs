//! Serving mode: N concurrent inference requests share one SoC on the
//! event-driven scheduler — per-request latency percentiles + aggregate
//! throughput, and the multi-accelerator scaling the serial per-op loop
//! cannot express.
//!
//! Run: `cargo run --release --example serving`

use smaug::config::{ServeOptions, SimOptions, SocConfig};
use smaug::nets;
use smaug::sim::Simulator;
use smaug::util::fmt_ns;

fn main() -> anyhow::Result<()> {
    let graph = nets::build_network("vgg16")?;
    let serve = ServeOptions {
        requests: 8,
        arrival_interval_ns: 100_000.0, // one request every 100 us
    };

    let mut baseline_rps = None;
    for accels in [1usize, 8] {
        let opts = SimOptions {
            num_accels: accels,
            sw_threads: 8,
            pipeline: true,
            ..SimOptions::default()
        };
        let report = Simulator::new(SocConfig::default(), opts).serve(&graph, &serve)?;
        println!("=== {accels} accelerator(s) ===");
        println!("{}", report.summary());
        let rps = report.throughput_rps();
        let base = *baseline_rps.get_or_insert(rps);
        println!(
            "p99 {}  |  {:.2}x throughput vs 1 accel\n",
            fmt_ns(report.latency_percentile(99.0)),
            rps / base
        );
    }
    Ok(())
}
