//! Fig 19 / Fig 20 reproduction: the camera-powered deep learning
//! pipeline — the Halide-style camera stages run functionally on a
//! synthetic 720p Bayer frame (CPU-timed), then CNN10 classifies the
//! downsampled frame on the systolic-array backend, against a 30 FPS
//! (33.3 ms) frame-time budget. A PE-configuration sweep shows where the
//! real-time constraint breaks.
//!
//! Run: `cargo run --release --example camera_pipeline`

use smaug::camera::{self, RawFrame};
use smaug::config::{AccelKind, SimOptions, SocConfig};
use smaug::nets;
use smaug::sim::Simulator;
use smaug::trace::Timeline;
use smaug::util::fmt_ns;

fn dnn_latency_ns(rows: usize, cols: usize) -> anyhow::Result<f64> {
    let mut soc = SocConfig::default();
    soc.systolic_rows = rows;
    soc.systolic_cols = cols;
    let opts = SimOptions {
        accel_kind: AccelKind::Systolic,
        ..SimOptions::default()
    };
    let g = nets::build_network("cnn10")?;
    Ok(Simulator::new(soc, opts).run(&g)?.total_ns)
}

fn main() -> anyhow::Result<()> {
    let budget_ms = 1000.0 / 30.0;
    let soc = SocConfig::default();

    // --- Fig 19: one frame through the full pipeline, with trace.
    println!("=== camera vision pipeline, one 720p frame (Fig 19) ===");
    let raw = RawFrame::synthetic(1280, 720, 42);
    let mut tl = Timeline::new(true);
    let (rgb, stages) = camera::run_pipeline(&raw, &soc, 1, Some(&mut tl));
    let cam_ns = camera::pipeline_ns(&stages);
    for s in &stages {
        println!("  {:<14} {:>12}", s.name, fmt_ns(s.ns));
    }
    // Downsample to the DNN input (functional).
    let small = camera::downsample(&rgb, 32, 32);
    assert_eq!(small.data.len(), 32 * 32 * 3);
    let dnn_ns = dnn_latency_ns(8, 8)?;
    println!(
        "  camera {} + DNN {} = frame {} (budget {:.1} ms, slack {:.1} ms)",
        fmt_ns(cam_ns),
        fmt_ns(dnn_ns),
        fmt_ns(cam_ns + dnn_ns),
        budget_ms,
        budget_ms - (cam_ns + dnn_ns) / 1e6
    );
    println!("\n{}", tl.ascii_gantt(90));

    // --- Fig 20: PE-array sweep.
    println!("=== systolic PE sweep (Fig 20) ===");
    println!(
        "{:<8} {:>12} {:>12} {:>10}",
        "PEs", "DNN", "frame", "30 FPS?"
    );
    let budget60_ms = 1000.0 / 60.0;
    println!(
        "{:<8} {:>12} {:>12} {:>10} {:>10}",
        "PEs", "DNN", "frame", "30 FPS?", "60 FPS?"
    );
    for (r, c) in [(8usize, 8usize), (4, 8), (4, 4), (2, 4), (2, 2), (1, 2), (1, 1)] {
        let dnn = dnn_latency_ns(r, c)?;
        let frame = cam_ns + dnn;
        let verdict = |b: f64| if frame / 1e6 <= b { "meets" } else { "VIOLATES" };
        println!(
            "{:<8} {:>12} {:>12} {:>10} {:>10}",
            format!("{r}x{c}"),
            fmt_ns(dnn),
            fmt_ns(frame),
            verdict(budget_ms),
            verdict(budget60_ms)
        );
    }
    println!(
        "\n(paper's testbed breaks at 4x4 @30FPS; our transaction-level\n\
         systolic model is faster per-tile, so the 30 FPS crossover shifts\n\
         to a smaller array, while at 60 FPS it lands near the paper's 4x4.\n\
         The qualitative cliff is preserved. See EXPERIMENTS.md.)"
    );
    Ok(())
}
