//! Fig 19 / Fig 20 reproduction: the camera-powered deep learning
//! pipeline — the Halide-style camera stages run functionally on a
//! synthetic 720p Bayer frame (CPU-timed), then CNN10 classifies the
//! downsampled frame on the systolic-array backend, against a 30 FPS
//! (33.3 ms) frame-time budget. A PE-configuration sweep shows where the
//! real-time constraint breaks.
//!
//! Run: `cargo run --release --example camera_pipeline`

use smaug::api::{Scenario, Session, Soc};
use smaug::camera::{self, RawFrame};
use smaug::config::SocConfig;
use smaug::trace::Timeline;
use smaug::util::fmt_ns;

fn frame_report(pe: (usize, usize), fps: f64) -> anyhow::Result<smaug::api::Report> {
    Session::on(Soc::default())
        .scenario(Scenario::Camera { fps, pe })
        .run()
}

fn main() -> anyhow::Result<()> {
    // --- Fig 19: one frame through the full pipeline, with trace.
    println!("=== camera vision pipeline, one 720p frame (Fig 19) ===");
    let raw = RawFrame::synthetic(1280, 720, 42);
    let mut tl = Timeline::new(true);
    let (rgb, _stages) = camera::run_pipeline(&raw, &SocConfig::default(), 1, Some(&mut tl));
    // Downsample to the DNN input (functional).
    let small = camera::downsample(&rgb, 32, 32);
    assert_eq!(small.data.len(), 32 * 32 * 3);

    let report = frame_report((8, 8), 30.0)?;
    let cam = report.camera.as_ref().expect("camera scenario");
    for (name, ns) in &cam.stages {
        println!("  {:<14} {:>12}", name, fmt_ns(*ns));
    }
    println!(
        "  camera {} + DNN {} = frame {} (budget {:.1} ms, slack {:.1} ms)",
        fmt_ns(cam.camera_ns),
        fmt_ns(cam.dnn_ns),
        fmt_ns(cam.frame_ns),
        cam.budget_ms,
        cam.budget_ms - cam.frame_ns / 1e6
    );
    println!("\n{}", tl.ascii_gantt(90));

    // --- Fig 20: PE-array sweep, one simulation per config; the frame
    // time is deterministic, so both FPS verdicts derive from it.
    println!("=== systolic PE sweep (Fig 20) ===");
    println!(
        "{:<8} {:>12} {:>12} {:>10} {:>10}",
        "PEs", "DNN", "frame", "30 FPS?", "60 FPS?"
    );
    for pe in [(8usize, 8usize), (4, 8), (4, 4), (2, 4), (2, 2), (1, 2), (1, 1)] {
        let r = frame_report(pe, 30.0)?;
        let c = r.camera.as_ref().unwrap();
        let frame_ms = c.frame_ns / 1e6;
        let verdict = |budget_ms: f64| if frame_ms <= budget_ms { "meets" } else { "VIOLATES" };
        println!(
            "{:<8} {:>12} {:>12} {:>10} {:>10}",
            format!("{}x{}", pe.0, pe.1),
            fmt_ns(c.dnn_ns),
            fmt_ns(c.frame_ns),
            verdict(1000.0 / 30.0),
            verdict(1000.0 / 60.0)
        );
    }
    println!(
        "\n(paper's testbed breaks at 4x4 @30FPS; our transaction-level\n\
         systolic model is faster per-tile, so the 30 FPS crossover shifts\n\
         to a smaller array, while at 60 FPS it lands near the paper's 4x4.\n\
         The qualitative cliff is preserved. See EXPERIMENTS.md.)"
    );
    Ok(())
}
