//! KV-cache decode serving: open-loop autoregressive decode steps share
//! one SoC. Each request is one `decode` step — a single token attending
//! over a 512-entry DRAM-resident KV cache — so the workload is
//! bandwidth-bound where the CNN zoo is compute-bound. The sweep below
//! shows the signature: widening DRAM from 1 to 4 channels collapses
//! decode p99 latency, while the same sweep barely moves vgg16
//! (compare `cargo run --release --example serving`).
//!
//! Run: `cargo run --release --example decode_serving`

use smaug::api::{Scenario, Session, Soc};
use smaug::config::ServeOptions;
use smaug::util::fmt_ns;

fn main() -> anyhow::Result<()> {
    // Open-loop Poisson decode steps at 20k steps/s with an SLO of 4x
    // the uncontended single-step latency.
    let mut serve = ServeOptions::poisson(32, 20_000.0);
    serve.slo_multiple = Some(4.0);
    let scenario = Scenario::Serving(serve);

    let mut baseline_p99 = None;
    for channels in [1usize, 2, 4] {
        let soc = Soc::builder().dram_channels(channels).build();
        let report = Session::on(soc)
            .network("decode")
            .threads(4)
            .scenario(scenario.clone())
            .run()?;
        println!("=== {channels} DRAM channel(s) ===");
        println!("{}", report.summary());
        let p99 = report.latency.map(|l| l.p99_ns).unwrap_or(0.0);
        let base = *baseline_p99.get_or_insert(p99);
        println!(
            "decode p99 {}  |  {:.2}x faster than 1 channel\n",
            fmt_ns(p99),
            base / p99.max(1e-12)
        );
    }
    Ok(())
}
